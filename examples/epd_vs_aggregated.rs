//! End-to-end validation driver (EXPERIMENTS.md §Real-engine):
//! serve the SAME multimodal workload through the real engine in EPD mode
//! and in aggregated (vLLM-like) mode, on live PJRT compute, and compare
//! TTFT / TPOT / throughput. This is the proof that all three layers
//! (Pallas kernels → JAX graphs → rust coordinator) compose.
//!
//! ```sh
//! make artifacts && cargo run --release --example epd_vs_aggregated
//! ```

use std::time::{Duration, Instant};

use epdserve::api::SubmitRequest;
use epdserve::core::config::EpdConfig;
use epdserve::core::topology::Topology;
use epdserve::engine::serve::{EngineConfig, EpdEngine};
use epdserve::util::rng::Rng;
use epdserve::util::stats::Summary;

const N_REQUESTS: usize = 48;
const RATE: f64 = 6.0; // req/s
const IMAGES: u32 = 4;
const MAX_TOKENS: u32 = 24;

fn run_mode(name: &str, epd: EpdConfig) -> anyhow::Result<(Summary, Summary, f64)> {
    println!("== {name}: starting engine ({} instances) ==", epd.instances.len());
    let engine = EpdEngine::start(EngineConfig::new("artifacts", epd))?;

    let mut rng = Rng::new(42);
    let mut rxs = Vec::new();
    let t0 = Instant::now();
    for _ in 0..N_REQUESTS {
        let gap = rng.exp(RATE);
        std::thread::sleep(Duration::from_secs_f64(gap));
        // (prompt content is irrelevant to the timing)
        let req = SubmitRequest::new("describe the attached frames")
            .images(IMAGES)
            .max_tokens(MAX_TOKENS)
            .seed(7);
        let (_, rx) = engine.submit_request(req)?;
        rxs.push(rx);
    }
    let mut completed = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(300))?.output()?;
        assert_eq!(resp.tokens.len(), MAX_TOKENS as usize);
        completed += 1;
    }
    let wall = t0.elapsed().as_secs_f64();
    let (ttfts, tpots, _lats) = engine.metrics.series();
    let throughput = completed as f64 / wall;
    println!(
        "   {completed}/{N_REQUESTS} done in {wall:.1}s  ({throughput:.2} req/s)  EP transfers: {} ({} MB)",
        engine
            .queues()
            .transfers
            .ep_count
            .load(std::sync::atomic::Ordering::Relaxed),
        engine
            .queues()
            .transfers
            .ep_bytes
            .load(std::sync::atomic::Ordering::Relaxed)
            / 1_000_000,
    );
    engine.shutdown();
    Ok((Summary::of(&ttfts), Summary::of(&tpots), throughput))
}

fn main() -> anyhow::Result<()> {
    epdserve::util::logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    let (epd_ttft, epd_tpot, epd_tp) =
        run_mode("EPD 2E1P1D", EpdConfig::epd(Topology::new(2, 1, 1), 1, 1, 128))?;
    let (agg_ttft, agg_tpot, agg_tp) =
        run_mode("Aggregated x4", EpdConfig::aggregated(4, 8))?;

    println!("\n== comparison (real engine, live PJRT compute, {N_REQUESTS} requests @ {RATE} r/s, {IMAGES} images/req) ==");
    println!("{:<14} {:>12} {:>12} {:>12} {:>14}", "system", "TTFT p50", "TTFT p90", "TPOT mean", "throughput");
    println!(
        "{:<14} {:>10.3}s {:>10.3}s {:>10.4}s {:>10.2} r/s",
        "EPD", epd_ttft.p50, epd_ttft.p90, epd_tpot.mean, epd_tp
    );
    println!(
        "{:<14} {:>10.3}s {:>10.3}s {:>10.4}s {:>10.2} r/s",
        "Aggregated", agg_ttft.p50, agg_ttft.p90, agg_tpot.mean, agg_tp
    );
    println!(
        "\nEPD vs aggregated: TTFT p50 {:.2}x, TPOT {:.2}x",
        agg_ttft.p50 / epd_ttft.p50.max(1e-9),
        agg_tpot.mean / epd_tpot.mean.max(1e-9),
    );
    Ok(())
}
