//! Start the HTTP frontend and exercise it with a client request —
//! demonstrates the OpenAI-flavoured API surface (Appendix E).
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_http
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use epdserve::core::config::EpdConfig;
use epdserve::core::topology::Topology;
use epdserve::engine::http::HttpServer;
use epdserve::engine::serve::{EngineConfig, EpdEngine};

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut out = String::new();
    stream.read_to_string(&mut out)?;
    Ok(out)
}

fn http_get(addr: &std::net::SocketAddr, path: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut out = String::new();
    stream.read_to_string(&mut out)?;
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    epdserve::util::logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let epd = EpdConfig::epd(Topology::new(1, 1, 1), 1, 1, 128);
    let engine = Arc::new(EpdEngine::start(EngineConfig::new("artifacts", epd))?);
    let server = HttpServer::serve(Arc::clone(&engine), "127.0.0.1:0")?;
    println!("serving on http://{}", server.addr);

    let resp = http_post(
        &server.addr,
        "/v1/completions",
        r#"{"prompt":"what do you see?","images":2,"max_tokens":12,"tenant":1,"priority":"interactive"}"#,
    )?;
    println!("\nPOST /v1/completions →\n{resp}");

    // Typed errors: out-of-range max_tokens is a field-level 400, not a
    // silent clamp.
    let bad = http_post(&server.addr, "/v1/completions", r#"{"max_tokens":99999}"#)?;
    println!("\nPOST /v1/completions (bad max_tokens) →\n{bad}");

    let metrics = http_get(&server.addr, "/metrics")?;
    println!("\nGET /metrics →\n{metrics}");

    server.stop();
    match Arc::try_unwrap(engine) {
        Ok(engine) => engine.shutdown(),
        Err(_) => {}
    }
    Ok(())
}
