//! Chaos-recovery demo: kill a worker mid-wave through the HTTP
//! frontend and watch the supervision layer redispatch the stranded
//! requests — no hung client, no silent loss, clean drain.
//!
//! The engine runs 2E2P1D on tiny_lmm with supervision armed, the
//! circuit-breaker layer on, and a deterministic fault plan that panics
//! one encoder after two jobs (instance 0 — a same-kind sibling always
//! survives). A burst of concurrent `/v1/completions` posts rides
//! through the kill; every response must be a 200 completion or a typed
//! 5xx, `/metrics` must show the crash and redispatch counters plus the
//! health-layer counters (the kill opens the dead worker's breaker,
//! nothing is lost), and a drain-mode shutdown must terminate with
//! nothing in flight.
//!
//! ```sh
//! make artifacts && cargo run --release --example chaos_recovery
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;

use epdserve::core::config::EpdConfig;
use epdserve::core::topology::Topology;
use epdserve::engine::http::HttpServer;
use epdserve::engine::serve::{EngineConfig, EpdEngine};
use epdserve::engine::EngineFaultPlan;

const N_REQUESTS: usize = 12;

fn http_post(addr: &std::net::SocketAddr, path: &str, body: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: localhost\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut out = String::new();
    stream.read_to_string(&mut out)?;
    Ok(out)
}

fn http_get(addr: &std::net::SocketAddr, path: &str) -> anyhow::Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    let req = format!("GET {path} HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n");
    stream.write_all(req.as_bytes())?;
    let mut out = String::new();
    stream.read_to_string(&mut out)?;
    Ok(out)
}

fn main() -> anyhow::Result<()> {
    epdserve::util::logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        // Exit 0 so CI smoke jobs can run this without artifacts.
        eprintln!("skipping chaos_recovery: artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }

    let mut epd = EpdConfig::epd(Topology::new(2, 2, 1), 1, 1, 128);
    epd.supervise = true;
    epd.supervise_heartbeat_ms = 0; // detect panics, not slow CI machines
    epd.retry_limit = 3;
    epd.retry_base_ms = 5;
    epd.drain_timeout_ms = 60_000;
    epd.sample_interval = 0.02;
    // Health-aware control plane: the seeded kill must surface as a
    // breaker transition in /metrics (a flapping worker would escalate
    // to quarantine — worker panics are one-shot here, so the smoke
    // asserts the open; the flap escalation is property-tested).
    epd.health_breaker = true;
    let mut cfg = EngineConfig::new("artifacts", epd);
    cfg.fault_plan = EngineFaultPlan::none().with_kill(0, 2);

    let engine = Arc::new(EpdEngine::start(cfg)?);
    let server = HttpServer::serve(Arc::clone(&engine), "127.0.0.1:0")?;
    println!("serving on http://{} (1 encoder armed to die)", server.addr);

    // Concurrent burst straddling the kill: every client must get an
    // HTTP answer — a completion or a typed error, never a hang.
    let mut clients = Vec::new();
    for i in 0..N_REQUESTS {
        let addr = server.addr;
        clients.push(std::thread::spawn(move || {
            let body = format!(
                r#"{{"prompt":"survive the kill","images":{},"max_tokens":6,"seed":{}}}"#,
                1 + i % 3,
                1000 + i
            );
            http_post(&addr, "/v1/completions", &body)
        }));
    }
    let mut ok = 0usize;
    let mut typed_errors = 0usize;
    for c in clients {
        let resp = c.join().expect("client thread")?;
        if resp.contains("200 OK") {
            ok += 1;
        } else if resp.contains("503") || resp.contains("504") {
            typed_errors += 1;
            println!("typed failure:\n{resp}");
        } else {
            anyhow::bail!("unexpected response:\n{resp}");
        }
    }
    println!("{ok} completions, {typed_errors} typed failures, 0 hangs");
    assert_eq!(ok + typed_errors, N_REQUESTS, "every client answered");

    let metrics = http_get(&server.addr, "/metrics")?;
    let body = metrics
        .split("\r\n\r\n")
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("no /metrics body"))?;
    let report = epdserve::util::json::Json::parse(body)?;
    let resilience = report
        .get("resilience")
        .ok_or_else(|| anyhow::anyhow!("/metrics missing resilience block"))?;
    let counter = |k: &str| -> f64 {
        resilience.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0)
    };
    println!("\nGET /metrics resilience →\n{}", resilience.pretty());
    assert!(counter("crashes") >= 1.0, "the seeded kill must surface in /metrics");
    assert!(
        counter("requests_retried") + counter("requests_retargeted") >= 1.0,
        "redispatch counters must move under a kill"
    );
    // Health-aware control plane: the kill feeds the breaker, the
    // surviving sibling keeps the loss count at zero, and every
    // health/hedge/budget counter is exposed for scrapers even at rest.
    assert!(counter("breaker_opens") >= 1.0, "the kill must open the dead worker's breaker");
    assert_eq!(counter("requests_lost") as u64, 0, "a surviving sibling means zero lost requests");
    for key in [
        "quarantines",
        "breaker_probes",
        "hedges_issued",
        "hedges_won",
        "hedges_cancelled",
        "retry_budget_exhausted",
    ] {
        assert!(
            resilience.get(key).is_some(),
            "/metrics resilience must expose the {key} counter"
        );
    }

    server.stop();
    match Arc::try_unwrap(engine) {
        Ok(engine) => {
            // Drain-mode shutdown: bounded by drain_timeout_ms, after
            // which any straggler gets a typed `draining` failure.
            engine.shutdown();
            println!("drained and shut down cleanly");
        }
        Err(engine) => {
            drop(engine);
            println!("frontend still holds the engine; skipping explicit drain");
        }
    }
    Ok(())
}
