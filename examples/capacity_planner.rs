//! Capacity planning with the analytical memory model: for each paper
//! model, show what disaggregation buys at each resolution — the
//! Figure 2 / Table 2 / Table 3 primitives as a planning tool.
//!
//! ```sh
//! cargo run --release --example capacity_planner
//! ```

use epdserve::model::memory::{MemoryModel, NodeKind};
use epdserve::model::spec::{DeviceSpec, LmmSpec, ModelId};
use epdserve::model::vision::Resolution;
use epdserve::util::bytes::human;

fn main() {
    for id in ModelId::all_paper_models() {
        let m = MemoryModel::new(LmmSpec::get(id), DeviceSpec::a100());
        println!("\n=== {} on {} ===", m.spec.name, m.device.name);
        println!(
            "weights: encoder {} + LLM {}; KV {} B/token",
            human(m.spec.encoder_weight_bytes()),
            human(m.spec.llm_weight_bytes()),
            m.spec.llm.kv_bytes_per_token(),
        );
        println!(
            "{:<12} {:>8} {:>22} {:>22} {:>20}",
            "resolution", "tiles", "imgs/req (agg->EPD)", "batch@10img (agg->E)", "KV tokens (agg->P)"
        );
        for res in Resolution::paper_set() {
            let tiles = epdserve::model::vision::tiles_for_image(&m.spec, res);
            let (i_agg, _) = m.max_images_per_request(NodeKind::Colocated, res, 0.8, 22);
            let (i_e, _) = m.max_images_per_request(NodeKind::EncodeOnly, res, 0.8, 22);
            let (i_p, _) = m.max_images_per_request(NodeKind::LlmOnly, res, 0.8, 22);
            let i_epd = i_e.min(i_p);
            let (b_agg, _) = m.max_batch(NodeKind::Colocated, 10, res, 0.8);
            let (b_e, _) = m.max_batch(NodeKind::EncodeOnly, 10, res, 0.8);
            let kv_agg = m.kv_capacity_tokens(NodeKind::Colocated, 0.8);
            let kv_p = m.kv_capacity_tokens(NodeKind::LlmOnly, 0.8);
            println!(
                "{:<12} {:>8} {:>12} -> {:<7} {:>12} -> {:<7} {:>9} -> {:<9}",
                res.to_string(),
                tiles,
                i_agg,
                i_epd,
                b_agg,
                b_e,
                kv_agg / 1000,
                format!("{}k", kv_p / 1000),
            );
        }
    }
    println!("\n(run `epdserve repro table2` / `table3` / `table8` for the full paper tables)");
}
