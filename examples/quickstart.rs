//! Quickstart: boot a 2E1P1D EPD engine over the AOT artifacts and serve a
//! handful of multimodal requests end to end.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use epdserve::core::config::EpdConfig;
use epdserve::core::topology::Topology;
use epdserve::engine::serve::{EngineConfig, EpdEngine};

fn main() -> anyhow::Result<()> {
    epdserve::util::logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    println!("starting EPD engine (2E1P1D) — each instance compiles its own executables…");
    let epd = EpdConfig::epd(Topology::new(2, 1, 1), 1, 1, 128);
    let engine = EpdEngine::start(EngineConfig::new("artifacts", epd))?;

    for (images, prompt) in [
        (1u32, "what is in this image?"),
        (2, "compare these two photos"),
        (4, "summarize the sequence of frames"),
    ] {
        let resp = engine.generate(images, prompt, 16)?;
        println!(
            "req {:>2}: images={images} -> {} tokens in {:.3}s  text={:?}",
            resp.id,
            resp.tokens.len(),
            resp.latency,
            truncate(&resp.text, 32),
        );
    }
    println!("\nmetrics: {}", engine.metrics.report().pretty());
    engine.shutdown();
    Ok(())
}

fn truncate(s: &str, n: usize) -> String {
    s.chars().take(n).collect()
}
