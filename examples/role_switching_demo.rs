//! Dynamic role switching on the REAL engine (§3.2.4): start 3E1P1D, hit
//! it with a decode-heavy workload shift (long outputs), and watch the
//! monitor move encode instances to decode.
//!
//! ```sh
//! make artifacts && cargo run --release --example role_switching_demo
//! ```

use std::time::Duration;

use epdserve::core::config::EpdConfig;

use epdserve::core::topology::Topology;
use epdserve::api::SubmitRequest;
use epdserve::coordinator::role_switch::SwitchPolicy;
use epdserve::engine::serve::{EngineConfig, EpdEngine};

fn main() -> anyhow::Result<()> {
    epdserve::util::logging::init();
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }
    let mut epd = EpdConfig::epd(Topology::new(3, 1, 1), 1, 1, 4);
    epd.role_switching = true;
    let mut cfg = EngineConfig::new("artifacts", epd);
    cfg.switch_policy = SwitchPolicy {
        imbalance_ratio: 2.0,
        min_pressure: 0.5,
        cooldown: 2.0,
        min_instances: 1,
        switch_time_with_e: 0.7,
        switch_time_pd: 0.1,
    };
    let engine = EpdEngine::start(cfg)?;

    let roles_snapshot = |engine: &EpdEngine| {
        let roles = engine.queues().roles.lock().unwrap().clone();
        roles.iter().map(|r| r.code()).collect::<String>()
    };
    println!("initial roles: {}", roles_snapshot(&engine));

    // Phase 1: encode-heavy, short outputs.
    let mut rxs = Vec::new();
    for _ in 0..8u64 {
        let req = SubmitRequest::new("short").images(4).max_tokens(4).seed(1);
        let (_, rx) = engine.submit_request(req)?;
        rxs.push(rx);
    }
    for rx in rxs.drain(..) {
        rx.recv_timeout(Duration::from_secs(120))?;
    }
    println!("after short-output phase: {}", roles_snapshot(&engine));

    // Phase 2: decode-heavy (long outputs) — pressure shifts to D.
    for _ in 0..24u64 {
        let req = SubmitRequest::new("long").images(1).max_tokens(200).seed(2);
        let (_, rx) = engine.submit_request(req)?;
        rxs.push(rx);
    }
    // Watch roles while the burst drains.
    for _ in 0..10 {
        std::thread::sleep(Duration::from_millis(600));
        let roles = roles_snapshot(&engine);
        let d_count = roles.matches('D').count();
        println!("roles: {roles}  (decode instances: {d_count})");
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(300))?;
    }
    let final_roles = roles_snapshot(&engine);
    println!("final roles: {final_roles}");
    println!(
        "decode instances grew from 1 to {}",
        final_roles.matches('D').count()
    );
    engine.shutdown();
    Ok(())
}
