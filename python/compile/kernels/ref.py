"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here; pytest
asserts allclose between the two across shapes/dtypes (see
python/tests/test_kernels.py). The references are deliberately naive —
clarity over speed.
"""

import jax.numpy as jnp


def patch_embed_ref(x, w, b):
    """[N, P] @ [P, D] + [D] -> [N, D]."""
    return x @ w + b


def attention_ref(q, k, v, causal: bool):
    """Multi-head attention.

    q: [T, H, D], k/v: [S, H, D] -> [T, H, D]. Softmax over S per head,
    optional causal mask (valid only when T == S up to an offset).
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    # [H, T, S]
    scores = jnp.einsum("thd,shd->hts", qf, kf) * scale
    if causal:
        t = q.shape[0]
        s = k.shape[0]
        mask = jnp.tril(jnp.ones((t, s), dtype=bool), k=s - t)
        scores = jnp.where(mask[None, :, :], scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hts,shd->thd", probs, vf)
    return out.astype(q.dtype)


def decode_attention_ref(q, k, v, lens):
    """Single-token decode attention against a padded KV cache.

    q: [B, H, D]; k/v: [B, H, S, D]; lens: [B] (valid KV length per seq).
    Returns [B, H, D].
    """
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    scores = jnp.einsum("bhd,bhsd->bhs", qf, kf) * scale  # [B, H, S]
    s = k.shape[2]
    mask = jnp.arange(s)[None, None, :] < lens[:, None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", probs, vf)
    return out.astype(q.dtype)
