"""L1: patch-embedding projection as a Pallas matmul kernel.

The conv-style patch projection is expressed as one MXU matmul
`[N, patch_dim] x [patch_dim, D]` (im2col done by free XLA reshapes in the
caller). The grid tiles N so each program's A-block plus the whole weight
panel fit in VMEM; at production sizes the weight panel would be double-
buffered across the K dimension.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 64


def _patch_embed_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [bn, P]
    w = w_ref[...].astype(jnp.float32)  # [P, D]
    b = b_ref[...].astype(jnp.float32)  # [1, D]
    o_ref[...] = (x @ w + b).astype(o_ref.dtype)


@jax.jit
def patch_embed(x, w, b):
    """[N, P] @ [P, D] + [D] -> [N, D] via a tiled Pallas matmul."""
    n, p = x.shape
    d = w.shape[1]
    bn = min(BLOCK_N, n)
    n_pad = (n + bn - 1) // bn * bn
    xp = jnp.pad(x, ((0, n_pad - n), (0, 0))) if n_pad != n else x
    out = pl.pallas_call(
        _patch_embed_kernel,
        grid=(n_pad // bn,),
        in_specs=[
            pl.BlockSpec((bn, p), lambda i: (i, 0)),
            pl.BlockSpec((p, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, d), x.dtype),
        interpret=True,
    )(xp, w, b.reshape(1, d))
    return out[:n]
