"""L1: fused multi-head attention as a Pallas kernel (flash-attention
style online softmax).

TPU adaptation of the paper's CUDA hot path (DESIGN.md §Hardware-
Adaptation): instead of warp-level softmax reductions over shared-memory
tiles, the grid is (heads, query-blocks); each program holds one query
block in VMEM via `BlockSpec`, streams the K/V sequence in `BLOCK_K`-sized
chunks, and maintains the running max / normalizer of the online softmax
in registers. QKᵀ and PV products map to the MXU.

Kernels are lowered with `interpret=True`: the CPU PJRT plugin cannot run
Mosaic custom-calls, and the interpret path lowers to plain HLO that the
rust runtime executes. Correctness vs `ref.attention_ref` is enforced by
pytest + hypothesis.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 32
BLOCK_K = 64
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal: bool, s_len: int,
                 block_k: int, q_offset_blocks: int):
    """One (head, q-block) program: online softmax over K/V chunks."""
    q = q_ref[...].astype(jnp.float32)  # [bq, D]
    bq, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    q = q * scale

    qi = pl.program_id(1)
    # Global row index of each query in this block.
    row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, 1), 0)

    num_kb = s_len // block_k

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(kb * block_k, block_k), slice(None)))
        k = k.astype(jnp.float32)
        v = v.astype(jnp.float32)
        scores = q @ k.T  # [bq, bk]
        if causal:
            col = kb * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1
            )
            mask = col <= row  # queries attend to keys at or before them
            scores = jnp.where(mask, scores, NEG_INF)
        m_new = jnp.maximum(m_prev, scores.max(axis=1, keepdims=True))
        p = jnp.exp(scores - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc = acc * alpha + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, 1), jnp.float32)
    acc, _, l = jax.lax.fori_loop(0, num_kb, body, (acc0, m0, l0))
    o_ref[...] = (acc / l).astype(o_ref.dtype)

    del q_offset_blocks  # reserved for future paged variants


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q, k, v, causal: bool = False):
    """Fused attention. q: [T, H, D]; k, v: [S, H, D] -> [T, H, D]."""
    t, h, d = q.shape
    s = k.shape[0]
    bq = min(BLOCK_Q, t)
    bk = min(BLOCK_K, s)
    # Pad sequence dims to block multiples (interpret path requires exact
    # tiling; padded key columns are masked out by construction only in the
    # causal case, so pad K with NEG_INF-producing zeros and rely on the
    # fact that padded *queries* are discarded and padded *keys* only occur
    # beyond s, handled by masking below through causal or explicit trim).
    t_pad = (t + bq - 1) // bq * bq
    s_pad = (s + bk - 1) // bk * bk

    # For non-causal attention padded keys would corrupt the softmax; mask
    # them by padding K with a large negative sentinel is not possible
    # (it enters via dot products). Instead require exact tiling for the
    # non-causal path and pad only queries.
    if not causal and s_pad != s:
        bk = _largest_divisor(s, BLOCK_K)
        s_pad = s

    qp = _pad_to(q, t_pad, 0)
    kp = _pad_to(k, s_pad, 0)
    vp = _pad_to(v, s_pad, 0)

    grid = (h, t_pad // bq)
    out = pl.pallas_call(
        functools.partial(
            _attn_kernel,
            causal=causal,
            s_len=s_pad,
            block_k=bk,
            q_offset_blocks=0,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda hh, qi: (hh, qi, 0)),
            pl.BlockSpec((None, s_pad, d), lambda hh, qi: (hh, 0, 0)),
            pl.BlockSpec((None, s_pad, d), lambda hh, qi: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda hh, qi: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t_pad, d), q.dtype),
        interpret=True,
    )(
        jnp.swapaxes(qp, 0, 1),  # [H, T, D]
        jnp.swapaxes(kp, 0, 1),
        jnp.swapaxes(vp, 0, 1),
    )
    out = jnp.swapaxes(out, 0, 1)[:t]
    return out


def _largest_divisor(n: int, cap: int) -> int:
    for b in range(min(n, cap), 0, -1):
        if n % b == 0:
            return b
    return 1
