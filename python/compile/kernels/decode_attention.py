"""L1: single-token decode attention against a padded KV cache.

The paper's decode hot path reads a *paged* KV cache via a block table.
On the TPU-style memory hierarchy we keep paging a coordinator concern
(the rust KV block manager) and hand the kernel a dense, `max_seq`-padded
KV slab per sequence plus the valid length — dense tiles stream HBM→VMEM
far better than gathers (DESIGN.md §Hardware-Adaptation).

Grid: (batch,). Each program computes ALL heads for one sequence in a
single pass — scores over the full padded S, a length mask from the
`lens` scalar, then a masked softmax. The per-program working set
(H × S × D f32 = 2 MiB at tiny-lmm sizes) still fits VMEM comfortably, and
collapsing the head axis removed an 8× sequential grid factor measured on
the CPU interpret path (EXPERIMENTS.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)  # [H, 1, D]
    k = k_ref[...].astype(jnp.float32)  # [H, S, D]
    v = v_ref[...].astype(jnp.float32)  # [H, S, D]
    length = lens_ref[0]

    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    # [H, 1, S] batched over heads in one program.
    scores = jnp.einsum("hqd,hsd->hqs", q, k) * scale
    pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 2)
    scores = jnp.where(pos < length, scores, NEG_INF)
    m = scores.max(axis=2, keepdims=True)
    p = jnp.exp(scores - m)
    l = p.sum(axis=2, keepdims=True)
    o = jnp.einsum("hqs,hsd->hqd", p, v) / l  # [H, 1, D]
    o_ref[...] = o.astype(o_ref.dtype)


@jax.jit
def decode_attention(q, k, v, lens):
    """q: [B, H, D]; k, v: [B, H, S, D]; lens: [B] -> [B, H, D]."""
    b, h, d = q.shape
    s = k.shape[2]
    grid = (b,)
    out = pl.pallas_call(
        _decode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda bb: (bb,)),
            pl.BlockSpec((None, h, 1, d), lambda bb: (bb, 0, 0, 0)),
            pl.BlockSpec((None, h, s, d), lambda bb: (bb, 0, 0, 0)),
            pl.BlockSpec((None, h, s, d), lambda bb: (bb, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, h, 1, d), lambda bb: (bb, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        interpret=True,
    )(lens.astype(jnp.int32), q[:, :, None, :], k, v)
    return out[:, :, 0, :]
