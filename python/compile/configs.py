"""Tiny-LMM architecture constants and AOT shape buckets.

These MUST stay in sync with `ModelId::TinyLmm` in rust/src/model/spec.rs
and with rust/src/runtime/artifacts.rs, which reads the manifest emitted by
aot.py.
"""

from dataclasses import dataclass, field


@dataclass(frozen=True)
class VisionConfig:
    """ViT-style encoder: 64x64 RGB images, 8x8 patches."""

    image_px: int = 64
    patch_px: int = 8
    channels: int = 3
    hidden: int = 128
    layers: int = 2
    heads: int = 4
    mlp_ratio: int = 4
    # Tokens emitted to the LLM per image tile (resampler output).
    out_tokens: int = 16

    @property
    def grid(self) -> int:
        return self.image_px // self.patch_px  # 8

    @property
    def num_patches(self) -> int:
        return self.grid * self.grid  # 64

    @property
    def patch_dim(self) -> int:
        return self.patch_px * self.patch_px * self.channels  # 192

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads  # 32

    @property
    def pool(self) -> int:
        """Patches pooled into one output token."""
        return self.num_patches // self.out_tokens  # 4


@dataclass(frozen=True)
class LlmConfig:
    """Decoder-only LM."""

    hidden: int = 256
    layers: int = 4
    heads: int = 8
    vocab: int = 512
    max_seq: int = 512
    mlp_ratio: int = 4

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads  # 32


@dataclass(frozen=True)
class Buckets:
    """Static-shape buckets compiled to separate HLO artifacts."""

    # Encoder batch sizes (tiles per invocation).
    encode_tiles: tuple = (1, 2, 4, 8, 16)
    # Prefill: images-per-request buckets; token length is derived.
    prefill_images: tuple = (1, 2, 4, 8)
    # Max text tokens (incl. BOS) padded into every prefill bucket.
    prefill_text: int = 32
    # Decode batch sizes.
    decode_batch: tuple = (1, 2, 4, 8)

    def prefill_tokens(self, images: int, vis: VisionConfig) -> int:
        """Total padded sequence length of a prefill bucket."""
        return self.prefill_text + images * vis.out_tokens


VISION = VisionConfig()
LLM = LlmConfig()
BUCKETS = Buckets()

# Control token ids (mirror rust/src/model/tokenizer.rs).
BOS = 256
EOS = 257
IMAGE_PLACEHOLDER = 258
PAD = 259
