"""AOT lowering: tiny-LMM stage graphs -> HLO text artifacts + weights.

Run once by ``make artifacts``::

    cd python && python -m compile.aot --out ../artifacts

Emits:

- ``encode_t{N}.hlo.txt``   one per encoder tile-batch bucket
- ``prefill_i{N}.hlo.txt``  one per images-per-request bucket
- ``decode_b{N}.hlo.txt``   one per decode batch bucket
- ``weights.bin``           all parameters, f32 LE, concatenated in
                            sorted-name order (the HLO parameter order)
- ``manifest.json``         weight table + artifact index + model config

Interchange format is HLO **text**, not a serialized HloModuleProto: the
rust side's xla_extension 0.5.1 rejects jax>=0.5 protos whose instruction
ids exceed INT_MAX; the text parser reassigns ids (see
/opt/xla-example/README.md).

Every executable takes the flattened parameter list first (sorted by
name — JAX's dict flattening order), then its runtime inputs; the rust
runtime (rust/src/runtime/artifacts.rs) relies on this, so the manifest
records both halves explicitly.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import BUCKETS, LLM, VISION
from . import model


def to_hlo_text(lowered, return_tuple: bool = True) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    return comp.as_hlo_text()


def lower_encode(params, tiles: int) -> str:
    spec = jax.ShapeDtypeStruct(
        (tiles, VISION.num_patches, VISION.patch_dim), jnp.float32
    )
    fn = lambda p, x: (model.encode_fn(p, x),)
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(params, spec))


def lower_prefill(params, images: int) -> str:
    t = BUCKETS.prefill_tokens(images, VISION)
    m = images * VISION.out_tokens
    tok = jax.ShapeDtypeStruct((t,), jnp.int32)
    mm = jax.ShapeDtypeStruct((m, LLM.hidden), jnp.float32)
    ln = jax.ShapeDtypeStruct((), jnp.int32)
    fn = lambda p, a, b, c: model.prefill_fn(p, a, b, c)
    return to_hlo_text(jax.jit(fn, keep_unused=True).lower(params, tok, mm, ln))


def lower_decode_logits(batch: int) -> str:
    """Companion executable: slice the [batch, vocab] logits prefix out of
    the fused decode state. The CPU PJRT plugin lacks partial raw host
    copies, so the runtime runs this tiny kernel instead of fetching the
    whole state (rust/src/runtime/tiny_lmm.rs)."""
    state = jax.ShapeDtypeStruct((model.decode_state_len(batch),), jnp.float32)
    fn = lambda st: (st[: batch * LLM.vocab].reshape(batch, LLM.vocab),)
    return to_hlo_text(jax.jit(fn).lower(state))


def lower_decode(params, batch: int) -> str:
    """Fused decode: flat [logits | kv] state in and out, non-tuple root so
    the rust runtime keeps the state buffer on device across steps."""
    tok = jax.ShapeDtypeStruct((batch,), jnp.int32)
    state = jax.ShapeDtypeStruct((model.decode_state_len(batch),), jnp.float32)
    ln = jax.ShapeDtypeStruct((batch,), jnp.int32)
    fn = lambda p, a, b, c: model.decode_fused_fn(p, a, b, c)
    return to_hlo_text(
        jax.jit(fn, keep_unused=True).lower(params, tok, state, ln),
        return_tuple=False,
    )


def build(out_dir: str, seed: int = 0, quiet: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    params = model.init_params(seed)
    names = sorted(params.keys())

    # ---- weights.bin + weight table ----
    weight_table = []
    offset = 0
    blobs = []
    for name in names:
        arr = np.asarray(params[name], dtype=np.float32)
        blobs.append(arr.tobytes())
        weight_table.append(
            {
                "name": name,
                "shape": list(arr.shape),
                "dtype": "f32",
                "offset": offset,
                "size_bytes": arr.nbytes,
            }
        )
        offset += arr.nbytes
    with open(os.path.join(out_dir, "weights.bin"), "wb") as f:
        for b in blobs:
            f.write(b)

    artifacts = {"encode": [], "prefill": [], "decode": []}

    def emit(name: str, text: str):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        if not quiet:
            print(f"  wrote {name} ({len(text) // 1024} KiB)")

    for tiles in BUCKETS.encode_tiles:
        fname = f"encode_t{tiles}.hlo.txt"
        emit(fname, lower_encode(params, tiles))
        artifacts["encode"].append(
            {
                "tiles": tiles,
                "file": fname,
                "inputs": [
                    {"name": "patches", "shape": [tiles, VISION.num_patches, VISION.patch_dim], "dtype": "f32"}
                ],
                "outputs": [
                    {"name": "mm_tokens", "shape": [tiles, VISION.out_tokens, LLM.hidden], "dtype": "f32"}
                ],
            }
        )

    for images in BUCKETS.prefill_images:
        t = BUCKETS.prefill_tokens(images, VISION)
        m = images * VISION.out_tokens
        fname = f"prefill_i{images}.hlo.txt"
        emit(fname, lower_prefill(params, images))
        artifacts["prefill"].append(
            {
                "images": images,
                "tokens": t,
                "mm_tokens": m,
                "file": fname,
                "inputs": [
                    {"name": "tokens", "shape": [t], "dtype": "i32"},
                    {"name": "mm", "shape": [m, LLM.hidden], "dtype": "f32"},
                    {"name": "length", "shape": [], "dtype": "i32"},
                ],
                "outputs": [
                    {"name": "logits", "shape": [LLM.vocab], "dtype": "f32"},
                    {
                        "name": "kv",
                        "shape": [LLM.layers, 2, LLM.heads, LLM.max_seq, LLM.head_dim],
                        "dtype": "f32",
                    },
                ],
            }
        )

    for batch in BUCKETS.decode_batch:
        fname = f"decode_b{batch}.hlo.txt"
        logits_fname = f"decode_logits_b{batch}.hlo.txt"
        emit(fname, lower_decode(params, batch))
        emit(logits_fname, lower_decode_logits(batch))
        artifacts["decode"].append(
            {
                "batch": batch,
                "file": fname,
                "state_len": model.decode_state_len(batch),
                "logits_file": logits_fname,
                "inputs": [
                    {"name": "token", "shape": [batch], "dtype": "i32"},
                    {"name": "state", "shape": [model.decode_state_len(batch)], "dtype": "f32"},
                    {"name": "cur_len", "shape": [batch], "dtype": "i32"},
                ],
                "outputs": [
                    {"name": "state", "shape": [model.decode_state_len(batch)], "dtype": "f32"}
                ],
            }
        )

    manifest = {
        "format_version": 1,
        "seed": seed,
        "weights_file": "weights.bin",
        "weights": weight_table,
        "artifacts": artifacts,
        "config": {
            "vision": {
                "image_px": VISION.image_px,
                "patch_px": VISION.patch_px,
                "num_patches": VISION.num_patches,
                "patch_dim": VISION.patch_dim,
                "hidden": VISION.hidden,
                "layers": VISION.layers,
                "out_tokens": VISION.out_tokens,
            },
            "llm": {
                "hidden": LLM.hidden,
                "layers": LLM.layers,
                "heads": LLM.heads,
                "head_dim": LLM.head_dim,
                "vocab": LLM.vocab,
                "max_seq": LLM.max_seq,
            },
            "buckets": {
                "encode_tiles": list(BUCKETS.encode_tiles),
                "prefill_images": list(BUCKETS.prefill_images),
                "prefill_text": BUCKETS.prefill_text,
                "decode_batch": list(BUCKETS.decode_batch),
            },
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if not quiet:
        total = sum(w["size_bytes"] for w in weight_table)
        print(f"  wrote weights.bin ({total // 1024} KiB, {len(weight_table)} tensors)")
        print(f"  wrote manifest.json")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    build(args.out, args.seed)


if __name__ == "__main__":
    main()
