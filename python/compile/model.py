"""L2: the tiny-LMM compute graphs (encode / prefill / decode) in JAX.

Three jittable functions mirror the paper's pipeline stages:

- ``encode_fn``:  image tiles -> multimodal tokens (the MME).
- ``prefill_fn``: prompt tokens + MM tokens -> KV cache + last logits.
- ``decode_fn``:  one token per sequence + KV cache -> next logits + KV.

All attention flows through the L1 Pallas kernels. Parameters are passed
as a flat ``{name: array}`` dict; JAX flattens dicts in sorted-key order,
which fixes the HLO parameter order the rust runtime relies on (see
aot.py's manifest).
"""

import jax
import jax.numpy as jnp

from .configs import BUCKETS, LLM, VISION, PAD
from .kernels.attention import attention
from .kernels.decode_attention import decode_attention
from .kernels.patch_embed import patch_embed


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(seed: int = 0):
    """Deterministic parameter dict for the tiny-LMM."""
    key = jax.random.PRNGKey(seed)
    params = {}

    def add(name, shape, scale=None):
        nonlocal key
        key, sub = jax.random.split(key)
        if scale is None:
            scale = 1.0 / jnp.sqrt(jnp.asarray(shape[0], jnp.float32))
        params[name] = (jax.random.normal(sub, shape, jnp.float32) * scale)

    v, l = VISION, LLM
    # Vision encoder.
    add("vis.patch_w", (v.patch_dim, v.hidden))
    add("vis.patch_b", (v.hidden,), scale=0.0)
    add("vis.pos", (v.num_patches, v.hidden), scale=0.02)
    for i in range(v.layers):
        p = f"vis.l{i}."
        add(p + "qkv_w", (v.hidden, 3 * v.hidden))
        add(p + "qkv_b", (3 * v.hidden,), scale=0.0)
        add(p + "o_w", (v.hidden, v.hidden))
        add(p + "o_b", (v.hidden,), scale=0.0)
        add(p + "mlp1_w", (v.hidden, v.mlp_ratio * v.hidden))
        add(p + "mlp1_b", (v.mlp_ratio * v.hidden,), scale=0.0)
        add(p + "mlp2_w", (v.mlp_ratio * v.hidden, v.hidden))
        add(p + "mlp2_b", (v.hidden,), scale=0.0)
        add(p + "ln1_g", (v.hidden,), scale=0.0)
        add(p + "ln2_g", (v.hidden,), scale=0.0)
    # Resampler: pool groups of patches, project into LLM space.
    add("vis.proj_w", (v.pool * v.hidden, l.hidden))
    add("vis.proj_b", (l.hidden,), scale=0.0)

    # LLM.
    add("llm.embed", (l.vocab, l.hidden), scale=0.02)
    add("llm.pos", (l.max_seq, l.hidden), scale=0.02)
    for i in range(l.layers):
        p = f"llm.l{i}."
        add(p + "qkv_w", (l.hidden, 3 * l.hidden))
        add(p + "qkv_b", (3 * l.hidden,), scale=0.0)
        add(p + "o_w", (l.hidden, l.hidden))
        add(p + "o_b", (l.hidden,), scale=0.0)
        add(p + "mlp1_w", (l.hidden, l.mlp_ratio * l.hidden))
        add(p + "mlp1_b", (l.mlp_ratio * l.hidden,), scale=0.0)
        add(p + "mlp2_w", (l.mlp_ratio * l.hidden, l.hidden))
        add(p + "mlp2_b", (l.hidden,), scale=0.0)
        add(p + "ln1_g", (l.hidden,), scale=0.0)
        add(p + "ln2_g", (l.hidden,), scale=0.0)
    add("llm.ln_f_g", (l.hidden,), scale=0.0)
    # Tied-ish but separate head for clarity.
    add("llm.head_w", (l.hidden, l.vocab))
    return params


def _ln(x, gain):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * (1.0 + gain)


# --------------------------------------------------------------------------
# Encoder (MME)
# --------------------------------------------------------------------------

def encode_fn(params, patches):
    """Encode image tiles.

    patches: [N, num_patches, patch_dim] -> MM tokens [N, out_tokens, llm_hidden].
    """
    v = VISION
    n = patches.shape[0]
    x = patch_embed(
        patches.reshape(n * v.num_patches, v.patch_dim),
        params["vis.patch_w"],
        params["vis.patch_b"],
    ).reshape(n, v.num_patches, v.hidden)
    x = x + params["vis.pos"][None]

    for i in range(v.layers):
        p = f"vis.l{i}."
        h = _ln(x, params[p + "ln1_g"])
        qkv = h @ params[p + "qkv_w"] + params[p + "qkv_b"]
        q, k, val = jnp.split(qkv, 3, axis=-1)

        def heads(t):
            return t.reshape(n, v.num_patches, v.heads, v.head_dim)

        # Full (non-causal) attention within each tile, via the Pallas
        # kernel, vmapped over tiles.
        att = jax.vmap(lambda qq, kk, vv: attention(qq, kk, vv, causal=False))(
            heads(q), heads(k), heads(val)
        )
        att = att.reshape(n, v.num_patches, v.hidden)
        x = x + att @ params[p + "o_w"] + params[p + "o_b"]
        h = _ln(x, params[p + "ln2_g"])
        h = jax.nn.gelu(h @ params[p + "mlp1_w"] + params[p + "mlp1_b"])
        x = x + h @ params[p + "mlp2_w"] + params[p + "mlp2_b"]

    # Resampler: group `pool` adjacent patches -> one LLM token.
    x = x.reshape(n, v.out_tokens, v.pool * v.hidden)
    return x @ params["vis.proj_w"] + params["vis.proj_b"]


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------

def prefill_fn(params, tokens, mm, length):
    """Prefill one sequence.

    tokens: [T] int32 — layout [BOS, <M image slots>, text..., PAD...].
    mm:     [M, hidden] — encoder output spliced into positions 1..1+M.
    length: [] int32 — true sequence length (1 + M + text tokens).

    Returns (logits [vocab], kv [layers, 2, heads, max_seq, head_dim]).
    """
    l = LLM
    t = tokens.shape[0]
    m = mm.shape[0]

    emb = params["llm.embed"][tokens]  # [T, H]
    emb = jnp.concatenate([emb[:1], mm, emb[1 + m:]], axis=0)
    x = emb + params["llm.pos"][:t]

    kv_layers = []
    for i in range(l.layers):
        p = f"llm.l{i}."
        h = _ln(x, params[p + "ln1_g"])
        qkv = h @ params[p + "qkv_w"] + params[p + "qkv_b"]
        q, k, val = jnp.split(qkv, 3, axis=-1)

        def heads(tensor):
            return tensor.reshape(t, l.heads, l.head_dim)

        att = attention(heads(q), heads(k), heads(val), causal=True)
        att = att.reshape(t, l.hidden)
        x = x + att @ params[p + "o_w"] + params[p + "o_b"]
        h = _ln(x, params[p + "ln2_g"])
        h = jax.nn.gelu(h @ params[p + "mlp1_w"] + params[p + "mlp1_b"])
        x = x + h @ params[p + "mlp2_w"] + params[p + "mlp2_b"]

        # KV padded to max_seq for direct use by the decode bucket.
        k_pad = jnp.zeros((l.heads, l.max_seq, l.head_dim), jnp.float32)
        v_pad = jnp.zeros((l.heads, l.max_seq, l.head_dim), jnp.float32)
        k_pad = k_pad.at[:, :t].set(jnp.swapaxes(heads(k), 0, 1))
        v_pad = v_pad.at[:, :t].set(jnp.swapaxes(heads(val), 0, 1))
        kv_layers.append(jnp.stack([k_pad, v_pad]))

    kv = jnp.stack(kv_layers)  # [L, 2, H, S, D]
    x = _ln(x, params["llm.ln_f_g"])
    last = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=0)[0]
    logits = last @ params["llm.head_w"]
    return logits, kv


# --------------------------------------------------------------------------
# Decode
# --------------------------------------------------------------------------

def decode_fn(params, token, kv, cur_len):
    """One decode step for a batch.

    token:   [B] int32 — current input token per sequence.
    kv:      [L, 2, B, H, S, D] — running KV cache.
    cur_len: [B] int32 — tokens already in the cache per sequence.

    Returns (logits [B, vocab], new_kv).
    """
    l = LLM
    b = token.shape[0]

    x = params["llm.embed"][token] + params["llm.pos"][cur_len]  # [B, H]

    new_layers = []
    for i in range(l.layers):
        p = f"llm.l{i}."
        h = _ln(x, params[p + "ln1_g"])
        qkv = h @ params[p + "qkv_w"] + params[p + "qkv_b"]
        q, k, val = jnp.split(qkv, 3, axis=-1)
        qh = q.reshape(b, l.heads, l.head_dim)
        kh = k.reshape(b, l.heads, l.head_dim)
        vh = val.reshape(b, l.heads, l.head_dim)

        # Write this step's K/V at position cur_len (per sequence).
        def write(cache, new):
            # cache: [B, H, S, D]; new: [B, H, D].
            def one(c, n_, pos):
                return jax.lax.dynamic_update_slice(c, n_[:, None, :], (0, pos, 0))

            return jax.vmap(one)(cache, new, cur_len)

        k_cache = write(kv[i, 0], kh)
        v_cache = write(kv[i, 1], vh)
        new_layers.append(jnp.stack([k_cache, v_cache]))

        att = decode_attention(qh, k_cache, v_cache, cur_len + 1)  # [B, H, D]
        att = att.reshape(b, l.hidden)
        x = x + att @ params[p + "o_w"] + params[p + "o_b"]
        h = _ln(x, params[p + "ln2_g"])
        h = jax.nn.gelu(h @ params[p + "mlp1_w"] + params[p + "mlp1_b"])
        x = x + h @ params[p + "mlp2_w"] + params[p + "mlp2_b"]

    new_kv = jnp.stack(new_layers)
    x = _ln(x, params["llm.ln_f_g"])
    logits = x @ params["llm.head_w"]
    return logits, new_kv


def decode_state_len(batch: int) -> int:
    """Flat f32 length of the fused decode state: [logits | kv]."""
    l = LLM
    return batch * l.vocab + l.layers * 2 * batch * l.heads * l.max_seq * l.head_dim


def decode_fused_fn(params, token, state, cur_len):
    """Decode step over a *fused* state vector.

    ``state`` is ``concat(prev_logits.flatten(), kv.flatten())`` — a single
    f32 array, so the lowered HLO has a non-tuple root and the rust runtime
    can keep one device-resident buffer across steps, reading back only the
    logits prefix each step (rust/src/runtime/tiny_lmm.rs).
    """
    l = LLM
    b = token.shape[0]
    kv = state[b * l.vocab :].reshape(
        l.layers, 2, b, l.heads, l.max_seq, l.head_dim
    )
    logits, new_kv = decode_fn(params, token, kv, cur_len)
    return jnp.concatenate([logits.reshape(-1), new_kv.reshape(-1)])


# --------------------------------------------------------------------------
# Host-side helpers (build-time + tests only)
# --------------------------------------------------------------------------

def make_patches(images):
    """[N, 64, 64, 3] uint8/float -> [N, num_patches, patch_dim] f32."""
    v = VISION
    n = images.shape[0]
    x = jnp.asarray(images, jnp.float32) / 255.0
    x = x.reshape(n, v.grid, v.patch_px, v.grid, v.patch_px, v.channels)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(n, v.num_patches, v.patch_dim)


def pad_tokens(tokens, images: int):
    """Pad a [BOS, placeholders, text] token list to its prefill bucket."""
    t_bucket = BUCKETS.prefill_tokens(images, VISION)
    out = list(tokens)[:t_bucket]
    length = len(out)
    out = out + [PAD] * (t_bucket - length)
    return jnp.asarray(out, jnp.int32), jnp.asarray(length, jnp.int32)
