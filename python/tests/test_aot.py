"""AOT path: lowered HLO text is well-formed and the manifest is complete.

Full lowering of every bucket happens in `make artifacts`; here we lower a
single representative of each stage (fast) and validate structure, then
check the manifest written by a real build when artifacts/ exists.
"""

import json
import os

import pytest

from compile import aot, model
from compile.configs import BUCKETS, LLM, VISION


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


def entry_param_count(text: str) -> int:
    """Parameters of the ENTRY computation (nested reducers also declare
    parameters, so a global count would overcount)."""
    entry = text[text.index("\nENTRY") :]
    entry = entry[: entry.index("\n}")]
    return entry.count("parameter(")


def test_hlo_text_well_formed_encode(params):
    text = aot.lower_encode(params, tiles=1)
    assert "HloModule" in text
    assert "ENTRY" in text
    # Weights (69) + 1 runtime input (keep_unused=True keeps all weights).
    assert entry_param_count(text) == 70


def test_hlo_text_well_formed_prefill(params):
    text = aot.lower_prefill(params, images=1)
    assert "HloModule" in text
    # Weights + tokens + mm + length.
    assert entry_param_count(text) == 72
    # Output is a tuple (logits, kv).
    assert "tuple(" in text


def test_hlo_text_well_formed_decode(params):
    text = aot.lower_decode(params, batch=2)
    assert "HloModule" in text
    assert entry_param_count(text) == 72


def test_full_build_manifest(tmp_path):
    manifest = aot.build(str(tmp_path), seed=0, quiet=True)
    # Weight table covers all parameters, contiguous offsets.
    names = sorted(p for p in model.init_params(0))
    assert [w["name"] for w in manifest["weights"]] == names
    offset = 0
    for w in manifest["weights"]:
        assert w["offset"] == offset
        offset += w["size_bytes"]
    assert os.path.getsize(tmp_path / "weights.bin") == offset

    # Every bucket has an artifact on disk.
    arts = manifest["artifacts"]
    assert len(arts["encode"]) == len(BUCKETS.encode_tiles)
    assert len(arts["prefill"]) == len(BUCKETS.prefill_images)
    assert len(arts["decode"]) == len(BUCKETS.decode_batch)
    for group in arts.values():
        for a in group:
            assert (tmp_path / a["file"]).exists()

    # Config mirrors the dataclasses (the rust runtime validates these).
    assert manifest["config"]["llm"]["vocab"] == LLM.vocab
    assert manifest["config"]["vision"]["out_tokens"] == VISION.out_tokens

    # Manifest is valid JSON on disk.
    with open(tmp_path / "manifest.json") as f:
        loaded = json.load(f)
    assert loaded["format_version"] == 1
