"""L2 correctness: tiny-LMM stage graphs compose — prefill+decode must be
exactly consistent with running the whole sequence through prefill."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.configs import BOS, BUCKETS, IMAGE_PLACEHOLDER, LLM, PAD, VISION


@pytest.fixture(scope="module")
def params():
    return model.init_params(0)


def mm_for(params, n_images, seed=7):
    rng = np.random.default_rng(seed)
    imgs = rng.integers(0, 255, size=(n_images, 64, 64, 3))
    mm = model.encode_fn(params, model.make_patches(imgs))
    return mm.reshape(-1, LLM.hidden)


def test_encode_shapes(params):
    for n in [1, 2, 4]:
        mm = mm_for(params, n)
        assert mm.shape == (n * VISION.out_tokens, LLM.hidden)
        assert bool(jnp.isfinite(mm).all())


def test_encode_deterministic(params):
    a = mm_for(params, 2, seed=3)
    b = mm_for(params, 2, seed=3)
    assert bool(jnp.array_equal(a, b))


def test_encode_tiles_independent(params):
    """IRP's premise: tiles encode independently, so encoding a batch must
    equal encoding each tile separately (modulo exact fp determinism)."""
    rng = np.random.default_rng(11)
    imgs = rng.integers(0, 255, size=(4, 64, 64, 3))
    patches = model.make_patches(imgs)
    full = model.encode_fn(params, patches)
    parts = jnp.concatenate(
        [model.encode_fn(params, patches[i : i + 1]) for i in range(4)], axis=0
    )
    np.testing.assert_allclose(np.asarray(full), np.asarray(parts), rtol=1e-5, atol=1e-5)


def test_prefill_shapes_and_finite(params):
    mm = mm_for(params, 2)
    toks = [BOS] + [IMAGE_PLACEHOLDER] * 32 + list(b"what is this?")
    tok, ln = model.pad_tokens(toks, 2)
    logits, kv = model.prefill_fn(params, tok, mm, ln)
    assert logits.shape == (LLM.vocab,)
    assert kv.shape == (LLM.layers, 2, LLM.heads, LLM.max_seq, LLM.head_dim)
    assert bool(jnp.isfinite(logits).all())


def test_prefill_ignores_padding(params):
    """Changing PAD tokens beyond `length` must not change the logits."""
    mm = mm_for(params, 1)
    toks = [BOS] + [IMAGE_PLACEHOLDER] * 16 + list(b"hi")
    tok, ln = model.pad_tokens(toks, 1)
    logits1, _ = model.prefill_fn(params, tok, mm, ln)
    tok2 = tok.at[int(ln) :].set(7)  # overwrite padding with a real token id
    logits2, _ = model.prefill_fn(params, tok2, mm, ln)
    np.testing.assert_allclose(np.asarray(logits1), np.asarray(logits2), rtol=1e-5)


def test_prefill_decode_consistency(params):
    """Greedy continuation via decode steps == prefill of the longer prompt.

    This is the end-to-end guarantee the serving engine relies on: the KV
    cache handed from P to D must produce identical next-token logits to
    recomputing from scratch.
    """
    mm = mm_for(params, 1)
    text = list(b"abc")
    toks = [BOS] + [IMAGE_PLACEHOLDER] * 16 + text
    tok, ln = model.pad_tokens(toks, 1)
    logits, kv = model.prefill_fn(params, tok, mm, ln)
    next_tok = int(jnp.argmax(logits))

    # Path A: one decode step from the prefill KV.
    kvb = kv[:, :, None]  # [L, 2, 1, H, S, D]
    lg_dec, _ = model.decode_fn(
        params, jnp.asarray([next_tok], jnp.int32), kvb, jnp.asarray([int(ln)], jnp.int32)
    )

    # Path B: prefill the prompt extended by next_tok (same bucket, fits
    # within padding).
    toks_b = toks + [next_tok]
    tok_b, ln_b = model.pad_tokens(toks_b, 1)
    lg_pf, _ = model.prefill_fn(params, tok_b, mm, ln_b)

    np.testing.assert_allclose(
        np.asarray(lg_dec[0]), np.asarray(lg_pf), rtol=2e-4, atol=2e-4
    )


def test_decode_batch_slots_independent(params):
    """Sequences in a decode batch must not leak into each other."""
    mm = mm_for(params, 1)
    toks = [BOS] + [IMAGE_PLACEHOLDER] * 16 + list(b"xy")
    tok, ln = model.pad_tokens(toks, 1)
    _, kv = model.prefill_fn(params, tok, mm, ln)

    kv2 = jnp.stack([kv, kv], axis=2)
    lens = jnp.asarray([int(ln), int(ln)], jnp.int32)
    t_same = jnp.asarray([65, 65], jnp.int32)
    lg_same, _ = model.decode_fn(params, t_same, kv2, lens)

    # Perturb slot 1's token; slot 0's logits must be unchanged.
    t_diff = jnp.asarray([65, 90], jnp.int32)
    lg_diff, _ = model.decode_fn(params, t_diff, kv2, lens)
    np.testing.assert_allclose(np.asarray(lg_same[0]), np.asarray(lg_diff[0]), rtol=1e-5)
    assert not np.allclose(np.asarray(lg_same[1]), np.asarray(lg_diff[1]))


def test_decode_kv_grows_at_cur_len(params):
    mm = mm_for(params, 1)
    toks = [BOS] + [IMAGE_PLACEHOLDER] * 16 + list(b"z")
    tok, ln = model.pad_tokens(toks, 1)
    _, kv = model.prefill_fn(params, tok, mm, ln)
    kvb = kv[:, :, None]
    pos = int(ln)
    t_bucket = BUCKETS.prefill_tokens(1, VISION)
    # Prefill fills the whole padded bucket; beyond it the cache is zero.
    assert float(jnp.abs(kvb[:, :, :, :, t_bucket:]).max()) == 0.0
    before = kvb[:, :, :, :, pos]
    _, kv_new = model.decode_fn(
        params, jnp.asarray([65], jnp.int32), kvb, jnp.asarray([pos], jnp.int32)
    )
    after = kv_new[:, :, :, :, pos]
    # The step overwrites the (padded) slot at cur_len with real K/V...
    assert not np.allclose(np.asarray(before), np.asarray(after))
    # ...and leaves every other slot untouched.
    mask = np.ones(kv_new.shape[4], dtype=bool)
    mask[pos] = False
    np.testing.assert_array_equal(
        np.asarray(kv_new)[:, :, :, :, mask], np.asarray(kvb)[:, :, :, :, mask]
    )


def test_pad_tokens_buckets():
    for n in BUCKETS.prefill_images:
        toks = [BOS] + [IMAGE_PLACEHOLDER] * (16 * n) + list(b"q")
        tok, ln = model.pad_tokens(toks, n)
        assert tok.shape[0] == BUCKETS.prefill_tokens(n, VISION)
        assert int(ln) == len(toks)
        assert int(tok[-1]) == PAD or int(ln) == tok.shape[0]
