"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Fixed-shape spot checks plus hypothesis sweeps over shapes and dtypes —
the CORE correctness signal for the compute layer (everything the rust
engine executes flows through these kernels).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import attention
from compile.kernels.decode_attention import decode_attention
from compile.kernels.patch_embed import patch_embed

RNG = np.random.default_rng(1234)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def assert_close(a, b, dtype=jnp.float32):
    np.testing.assert_allclose(
        np.asarray(a, np.float32),
        np.asarray(b, np.float32),
        rtol=TOL[dtype],
        atol=TOL[dtype] * 10,
    )


# ---------------------------------------------------------------- attention

@pytest.mark.parametrize("t,h,d", [(16, 2, 16), (48, 4, 32), (64, 8, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_attention_matches_ref(t, h, d, causal):
    q, k, v = randn((t, h, d)), randn((t, h, d)), randn((t, h, d))
    assert_close(attention(q, k, v, causal=causal), ref.attention_ref(q, k, v, causal))


def test_attention_cross_lengths_non_causal():
    q = randn((8, 2, 16))
    k = randn((24, 2, 16))
    v = randn((24, 2, 16))
    assert_close(attention(q, k, v, causal=False), ref.attention_ref(q, k, v, False))


def test_attention_causal_first_token_sees_only_itself():
    t, h, d = 8, 2, 16
    q, k = randn((t, h, d)), randn((t, h, d))
    v = randn((t, h, d))
    out = attention(q, k, v, causal=True)
    # Row 0 attends only to position 0 → output == v[0].
    assert_close(out[0], v[0])


def test_attention_bfloat16():
    q = randn((32, 4, 32), jnp.bfloat16)
    k = randn((32, 4, 32), jnp.bfloat16)
    v = randn((32, 4, 32), jnp.bfloat16)
    assert_close(
        attention(q, k, v, causal=True),
        ref.attention_ref(q, k, v, True),
        jnp.bfloat16,
    )


@settings(max_examples=25, deadline=None)
@given(
    t=st.integers(1, 96),
    h=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_hypothesis(t, h, d, causal, seed):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(t, h, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(t, h, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(t, h, d)), jnp.float32)
    assert_close(attention(q, k, v, causal=causal), ref.attention_ref(q, k, v, causal))


# ---------------------------------------------------------- decode attention

@pytest.mark.parametrize("b,h,s,d", [(1, 2, 32, 16), (4, 8, 512, 32)])
def test_decode_attention_matches_ref(b, h, s, d):
    q = randn((b, h, d))
    k = randn((b, h, s, d))
    v = randn((b, h, s, d))
    lens = jnp.asarray(RNG.integers(1, s + 1, size=(b,)), jnp.int32)
    assert_close(decode_attention(q, k, v, lens), ref.decode_attention_ref(q, k, v, lens))


def test_decode_attention_masks_padded_tail():
    # Garbage beyond `lens` must not affect the output.
    b, h, s, d = 2, 4, 64, 16
    q = randn((b, h, d))
    k = randn((b, h, s, d))
    v = randn((b, h, s, d))
    lens = jnp.asarray([10, 20], jnp.int32)
    out1 = decode_attention(q, k, v, lens)
    k2 = k.at[:, :, 32:].set(1e6)
    v2 = v.at[:, :, 32:].set(-1e6)
    out2 = decode_attention(q, k2, v2, lens)
    assert_close(out1, out2)


def test_decode_attention_len1_returns_v0():
    b, h, s, d = 1, 2, 16, 8
    q = randn((b, h, d))
    k = randn((b, h, s, d))
    v = randn((b, h, s, d))
    out = decode_attention(q, k, v, jnp.asarray([1], jnp.int32))
    assert_close(out[0], v[0, :, 0, :])


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    h=st.sampled_from([2, 8]),
    s=st.sampled_from([16, 64, 512]),
    seed=st.integers(0, 2**16),
)
def test_decode_attention_hypothesis(b, h, s, seed):
    d = 32
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.normal(size=(b, h, d)), jnp.float32)
    k = jnp.asarray(r.normal(size=(b, h, s, d)), jnp.float32)
    v = jnp.asarray(r.normal(size=(b, h, s, d)), jnp.float32)
    lens = jnp.asarray(r.integers(1, s + 1, size=(b,)), jnp.int32)
    assert_close(decode_attention(q, k, v, lens), ref.decode_attention_ref(q, k, v, lens))


# --------------------------------------------------------------- patch embed

@pytest.mark.parametrize("n", [1, 63, 64, 65, 256])
def test_patch_embed_matches_ref(n):
    x = randn((n, 192))
    w = randn((192, 128))
    b = randn((128,))
    assert_close(patch_embed(x, w, b), ref.patch_embed_ref(x, w, b))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 200),
    p=st.sampled_from([16, 64, 192]),
    d=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**16),
)
def test_patch_embed_hypothesis(n, p, d, seed):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(n, p)), jnp.float32)
    w = jnp.asarray(r.normal(size=(p, d)), jnp.float32)
    b = jnp.asarray(r.normal(size=(d,)), jnp.float32)
    assert_close(patch_embed(x, w, b), ref.patch_embed_ref(x, w, b))
