//! The one resilience-counter schema shared by both halves of the stack.
//!
//! The simulator's [`ResilienceStats`](crate::sim::fault::ResilienceStats)
//! and the engine's [`MetricsRecorder`](crate::metrics::recorder) used to
//! maintain parallel hand-matched field lists; every new counter had to be
//! added twice and could silently drift. [`ResilienceCounters`] is the
//! shared core: the sim embeds it (and `Deref`s to it so existing field
//! accesses keep working), the recorder snapshots its atomics into it, and
//! both JSON reports emit [`ResilienceCounters::json_fields`] so the
//! schema cannot diverge. Side-specific extras (the sim's chaos event
//! counts and recovery metrics, the engine's deadline/drain failures) are
//! appended after the shared fields by their owners.

use crate::router::health::HealthStats;
use crate::util::json::Json;

/// Resilience counters with identical meaning in the simulator and the
/// real engine. All zeros unless faults fire or a health-layer knob is on.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResilienceCounters {
    /// Instance crashes executed (sim) / observed by the supervisor
    /// (engine); deduplicated to one per instance death.
    pub crashes: u64,
    /// Requests terminally failed by instance loss. Lost requests still
    /// count toward the termination ledger so conservation holds:
    /// sim `finished + rejected + lost == submitted`, engine
    /// `finished + failed == submitted`.
    pub requests_lost: u64,
    /// Work items re-queued to a sibling after a crash drain or abort.
    pub requests_retried: u64,
    /// Decode-side reservations/work re-targeted off a dead instance.
    pub requests_retargeted: u64,
    /// Circuit-breaker Closed/Half-Open → Open transitions.
    pub breaker_opens: u64,
    /// Half-Open probe admissions granted by the breaker.
    pub breaker_probes: u64,
    /// Flapping instances escalated into quarantine.
    pub quarantines: u64,
    /// Duplicate dispatches issued for slow in-flight requests.
    pub hedges_issued: u64,
    /// Hedges whose duplicate completed first (the hedge paid off).
    pub hedges_won: u64,
    /// Hedge copies cancelled after the other leg completed first.
    pub hedges_cancelled: u64,
    /// Redispatches converted to typed sheds by the exhausted cluster
    /// retry budget.
    pub retry_budget_exhausted: u64,
}

impl ResilienceCounters {
    /// The shared JSON schema, in canonical field order. Owners append
    /// their side-specific fields after these.
    pub fn json_fields(&self) -> Vec<(&'static str, Json)> {
        vec![
            ("crashes", Json::num(self.crashes as f64)),
            ("requests_lost", Json::num(self.requests_lost as f64)),
            ("requests_retried", Json::num(self.requests_retried as f64)),
            ("requests_retargeted", Json::num(self.requests_retargeted as f64)),
            ("breaker_opens", Json::num(self.breaker_opens as f64)),
            ("breaker_probes", Json::num(self.breaker_probes as f64)),
            ("quarantines", Json::num(self.quarantines as f64)),
            ("hedges_issued", Json::num(self.hedges_issued as f64)),
            ("hedges_won", Json::num(self.hedges_won as f64)),
            ("hedges_cancelled", Json::num(self.hedges_cancelled as f64)),
            ("retry_budget_exhausted", Json::num(self.retry_budget_exhausted as f64)),
        ]
    }

    pub fn to_json(&self) -> Json {
        Json::obj(self.json_fields())
    }

    /// Overwrite the breaker-side counters from a
    /// [`HealthTracker`](crate::router::health::HealthTracker) snapshot
    /// (the tracker owns those counts; end-of-run sync point).
    pub fn absorb_health(&mut self, h: &HealthStats) {
        self.breaker_opens = h.breaker_opens;
        self.breaker_probes = h.breaker_probes;
        self.quarantines = h.quarantines;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_has_every_field_and_defaults_to_zero() {
        let c = ResilienceCounters::default();
        let j = c.to_json();
        for (name, _) in c.json_fields() {
            assert_eq!(j.get(name).unwrap().as_f64(), Some(0.0), "{name}");
        }
        assert_eq!(c.json_fields().len(), 11);
    }

    #[test]
    fn absorb_health_overwrites_breaker_counters_only() {
        let mut c = ResilienceCounters { crashes: 3, hedges_issued: 2, ..Default::default() };
        c.absorb_health(&HealthStats { breaker_opens: 4, quarantines: 1, breaker_probes: 9 });
        assert_eq!(c.crashes, 3);
        assert_eq!(c.hedges_issued, 2);
        assert_eq!(c.breaker_opens, 4);
        assert_eq!(c.breaker_probes, 9);
        assert_eq!(c.quarantines, 1);
    }
}
