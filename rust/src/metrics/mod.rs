//! Evaluation metrics (§4): TTFT, TPOT, SLO attainment, and goodput — "the
//! highest request rate at which 90% or more SLO attainment is achieved".

pub mod goodput;
pub mod recorder;
pub mod resilience;

pub use goodput::{find_goodput, GoodputResult};
pub use recorder::MetricsRecorder;
pub use resilience::ResilienceCounters;
