//! Goodput search: the highest λ with ≥ 90% SLO attainment, found by
//! doubling + bisection over a caller-supplied evaluation function
//! (normally a simulator run at rate λ).

/// Result of a goodput search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GoodputResult {
    /// Highest rate (req/s) sustaining the attainment threshold; 0 when
    /// even the lowest probed rate misses it.
    pub goodput: f64,
    /// Attainment measured at `goodput`.
    pub attainment: f64,
    /// Evaluation calls spent.
    pub evals: u32,
}

/// Find goodput by exponential bracketing then bisection.
///
/// `eval(rate)` must return SLO attainment in [0, 1] for a run at `rate`.
/// `lo_rate` seeds the search (must be > 0); `tol` is the relative rate
/// resolution at which bisection stops.
pub fn find_goodput<F: FnMut(f64) -> f64>(
    mut eval: F,
    lo_rate: f64,
    threshold: f64,
    tol: f64,
) -> GoodputResult {
    assert!(lo_rate > 0.0 && threshold > 0.0 && threshold <= 1.0);
    let mut evals = 0u32;
    let mut probe = |r: f64, evals: &mut u32| {
        *evals += 1;
        eval(r)
    };

    // The lowest rate must pass, otherwise goodput is 0.
    let base = probe(lo_rate, &mut evals);
    if base < threshold {
        return GoodputResult { goodput: 0.0, attainment: base, evals };
    }

    // Exponential growth until failure (or a generous cap).
    let mut lo = lo_rate;
    let mut lo_att = base;
    let mut hi = lo_rate;
    let mut failed = false;
    for _ in 0..20 {
        hi *= 2.0;
        let att = probe(hi, &mut evals);
        if att < threshold {
            failed = true;
            break;
        }
        lo = hi;
        lo_att = att;
    }
    if !failed {
        // Saturation never reached — report the bracket edge.
        return GoodputResult { goodput: lo, attainment: lo_att, evals };
    }

    // Bisect (lo passes, hi fails).
    while (hi - lo) / lo > tol {
        let mid = 0.5 * (lo + hi);
        let att = probe(mid, &mut evals);
        if att >= threshold {
            lo = mid;
            lo_att = att;
        } else {
            hi = mid;
        }
    }
    GoodputResult { goodput: lo, attainment: lo_att, evals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_step_boundary() {
        // Attainment is 1.0 below rate 3.7, 0 above.
        let r = find_goodput(|rate| if rate <= 3.7 { 1.0 } else { 0.0 }, 0.1, 0.9, 0.01);
        assert!((r.goodput - 3.7).abs() < 0.08, "goodput {}", r.goodput);
        assert!(r.attainment >= 0.9);
    }

    #[test]
    fn zero_when_never_attained() {
        let r = find_goodput(|_| 0.5, 0.1, 0.9, 0.01);
        assert_eq!(r.goodput, 0.0);
    }

    #[test]
    fn saturates_cap_when_always_attained() {
        let r = find_goodput(|_| 1.0, 0.1, 0.9, 0.01);
        assert!(r.goodput > 10_000.0, "cap edge {}", r.goodput);
    }

    #[test]
    fn smooth_degradation() {
        // Attainment falls linearly from 1.0 at rate 0 to 0 at rate 10 —
        // 90% attainment crossing at rate 1.0.
        let r = find_goodput(|rate| (1.0 - rate / 10.0).max(0.0), 0.05, 0.9, 0.005);
        assert!((r.goodput - 1.0).abs() < 0.05, "goodput {}", r.goodput);
    }
}
