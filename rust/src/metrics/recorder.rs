//! Wall-clock metrics recorder for the real engine: thread-safe TTFT/TPOT
//! collection plus derived reports. (The simulator computes metrics from
//! virtual-time timelines instead; this type is for live serving.)

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::planner::ReallocationStats;
use crate::core::request::RequestId;
use crate::core::slo::Slo;
use crate::core::stage::Stage;
use crate::metrics::resilience::ResilienceCounters;
use crate::router::health::HealthStats;
use crate::util::json::Json;
use crate::util::stats::Summary;

#[derive(Debug, Clone, Copy)]
struct Record {
    arrival: Instant,
    first_token: Option<Instant>,
    finish: Option<Instant>,
    output_tokens: u32,
}

/// Thread-safe live metrics store.
#[derive(Debug, Default)]
pub struct MetricsRecorder {
    inner: Mutex<Vec<(RequestId, Record)>>,
    /// Cross-request encoder-cache lookups that skipped encode.
    enc_cache_hits: AtomicU64,
    /// Lookups that went through the full encode path.
    enc_cache_misses: AtomicU64,
    /// Streamed EP chunks emitted by encode shards (chunked handoff).
    ep_chunks: AtomicU64,
    /// Requests admitted through the streamed EP pipeline.
    ep_streamed: AtomicU64,
    /// Streamed requests whose chunks finished reassembly at prefill.
    ep_reassembled: AtomicU64,
    /// KV layer groups emitted by prefill (streamed PD handoff).
    pd_chunks: AtomicU64,
    /// Requests whose KV left prefill as layer groups.
    pd_streamed: AtomicU64,
    /// Streamed requests whose KV finished reassembly at decode.
    pd_reassembled: AtomicU64,
    /// Worker-side per-stage busy time (nanoseconds, indexed by
    /// `Stage::index`) — the monitor thread's busy-fraction signal.
    stage_busy_ns: [AtomicU64; 3],
    /// Worker-side per-stage completed jobs — with `stage_busy_ns`, the
    /// monitor's per-job service-time EWMA source.
    stage_jobs: [AtomicU64; 3],
    /// Request-shape accumulators (images / prompt tokens / requested
    /// output tokens over all submissions) the profiler turns into EWMAs.
    arrived_images: AtomicU64,
    arrived_prompt_tokens: AtomicU64,
    arrived_output_tokens: AtomicU64,
    /// Front-door admission counters (`EpdEngine::submit_request` with
    /// `router = "on"`): requests refused with 429, requests served
    /// degraded (capped tokens, batch class).
    router_shed: AtomicU64,
    router_degraded: AtomicU64,
    /// Reallocation counters: executed role switches plus the planner's
    /// plan/step snapshot (mirrored from the monitor thread).
    role_switches: AtomicU64,
    plans: AtomicU64,
    planned_steps: AtomicU64,
    released_steps: AtomicU64,
    blocked_steps: AtomicU64,
    aborted_plans: AtomicU64,
    surrogate_scored: AtomicU64,
    whatif_evals: AtomicU64,
    forced_explorations: AtomicU64,
    /// Supervision & recovery counters (named for parity with the
    /// simulator's `ResilienceStats` so sim and engine dashboards line
    /// up): worker crashes observed by the supervisor, requests
    /// terminally lost to worker death, swept/errored work re-dispatched
    /// to an encode/prefill sibling, decode-side work re-targeted after
    /// a crash, deadline (504) cancellations, per-request degradations
    /// to the monolithic path, requests failed by the drain bound, and
    /// total typed failures (the `finished + failed == submitted`
    /// ledger's failure side).
    crashes: AtomicU64,
    requests_lost: AtomicU64,
    requests_retried: AtomicU64,
    requests_retargeted: AtomicU64,
    deadline_exceeded: AtomicU64,
    degraded_fallbacks: AtomicU64,
    drain_failed: AtomicU64,
    failed: AtomicU64,
    /// Health-layer counters (shared schema with the simulator via
    /// `metrics::resilience::ResilienceCounters`): breaker transitions
    /// mirrored from the supervisor's `HealthTracker` snapshot, hedge
    /// lifecycle events, and redispatches shed by the cluster retry
    /// budget.
    breaker_opens: AtomicU64,
    breaker_probes: AtomicU64,
    quarantines: AtomicU64,
    hedges_issued: AtomicU64,
    hedges_won: AtomicU64,
    hedges_cancelled: AtomicU64,
    retry_budget_exhausted: AtomicU64,
}

impl MetricsRecorder {
    pub fn new() -> MetricsRecorder {
        MetricsRecorder::default()
    }

    /// Record an encoder-cache lookup outcome at admission.
    pub fn on_encoder_cache(&self, hit: bool) {
        if hit {
            self.enc_cache_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.enc_cache_misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn encoder_cache_hits(&self) -> u64 {
        self.enc_cache_hits.load(Ordering::Relaxed)
    }

    pub fn encoder_cache_misses(&self) -> u64 {
        self.enc_cache_misses.load(Ordering::Relaxed)
    }

    /// Hits over lookups, in [0, 1]; 0 before any media request arrived.
    pub fn encoder_cache_hit_rate(&self) -> f64 {
        let h = self.encoder_cache_hits();
        let m = self.encoder_cache_misses();
        if h + m == 0 {
            return 0.0;
        }
        h as f64 / (h + m) as f64
    }

    /// Record one streamed EP chunk leaving an encode shard (the TTFT-
    /// overlap signal: chunks landing before the last shard merges are
    /// prefill-side work the monolithic handoff would have serialized).
    pub fn on_ep_chunk(&self) {
        self.ep_chunks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request entering the streamed EP pipeline at submit.
    pub fn on_ep_streamed(&self) {
        self.ep_streamed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a streamed request completing prefill-side reassembly.
    pub fn on_ep_reassembled(&self) {
        self.ep_reassembled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn ep_chunks(&self) -> u64 {
        self.ep_chunks.load(Ordering::Relaxed)
    }

    pub fn ep_streamed_requests(&self) -> u64 {
        self.ep_streamed.load(Ordering::Relaxed)
    }

    pub fn ep_reassembled_requests(&self) -> u64 {
        self.ep_reassembled.load(Ordering::Relaxed)
    }

    /// Record one KV layer group leaving prefill (streamed PD handoff,
    /// `EpdConfig::pd_layer_groups > 0`).
    pub fn on_pd_chunk(&self) {
        self.pd_chunks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request whose prefilled KV left as layer groups.
    pub fn on_pd_streamed(&self) {
        self.pd_streamed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a streamed request completing decode-side KV reassembly.
    pub fn on_pd_reassembled(&self) {
        self.pd_reassembled.fetch_add(1, Ordering::Relaxed);
    }

    pub fn pd_chunks(&self) -> u64 {
        self.pd_chunks.load(Ordering::Relaxed)
    }

    pub fn pd_streamed_requests(&self) -> u64 {
        self.pd_streamed.load(Ordering::Relaxed)
    }

    pub fn pd_reassembled_requests(&self) -> u64 {
        self.pd_reassembled.load(Ordering::Relaxed)
    }

    /// Record `seconds` of stage work covering `jobs` completed jobs on a
    /// worker thread (the handle/decode-batch call sites in
    /// `engine/instance.rs`). These counters replace the monitor's old
    /// `qlen`-as-backlog proxy and hard-coded zero utilization.
    pub fn on_stage_work(&self, stage: Stage, seconds: f64, jobs: u64) {
        let ns = (seconds.max(0.0) * 1e9) as u64;
        self.stage_busy_ns[stage.index()].fetch_add(ns, Ordering::Relaxed);
        self.stage_jobs[stage.index()].fetch_add(jobs, Ordering::Relaxed);
    }

    /// Cumulative worker busy time for a stage, seconds.
    pub fn stage_busy_seconds(&self, stage: Stage) -> f64 {
        self.stage_busy_ns[stage.index()].load(Ordering::Relaxed) as f64 * 1e-9
    }

    /// Cumulative jobs completed for a stage.
    pub fn stage_jobs(&self, stage: Stage) -> u64 {
        self.stage_jobs[stage.index()].load(Ordering::Relaxed)
    }

    /// Record a submitted request's shape (profiler EWMA source).
    pub fn on_request_shape(&self, images: u32, prompt_tokens: u32, output_tokens: u32) {
        self.arrived_images.fetch_add(images as u64, Ordering::Relaxed);
        self.arrived_prompt_tokens
            .fetch_add(prompt_tokens as u64, Ordering::Relaxed);
        self.arrived_output_tokens
            .fetch_add(output_tokens as u64, Ordering::Relaxed);
    }

    /// Cumulative (images, prompt tokens, output tokens) over submissions.
    pub fn request_shape_totals(&self) -> (u64, u64, u64) {
        (
            self.arrived_images.load(Ordering::Relaxed),
            self.arrived_prompt_tokens.load(Ordering::Relaxed),
            self.arrived_output_tokens.load(Ordering::Relaxed),
        )
    }

    /// Record one shed (429) submission.
    pub fn on_router_shed(&self) {
        self.router_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one degraded admission.
    pub fn on_router_degraded(&self) {
        self.router_degraded.fetch_add(1, Ordering::Relaxed);
    }

    pub fn router_shed(&self) -> u64 {
        self.router_shed.load(Ordering::Relaxed)
    }

    pub fn router_degraded(&self) -> u64 {
        self.router_degraded.load(Ordering::Relaxed)
    }

    /// Record one executed role switch (monitor thread).
    pub fn on_role_switch(&self) {
        self.role_switches.fetch_add(1, Ordering::Relaxed);
    }

    pub fn role_switches(&self) -> u64 {
        self.role_switches.load(Ordering::Relaxed)
    }

    /// Mirror the planner's counters (monitor thread, once per tick).
    pub fn record_reallocation(&self, stats: ReallocationStats) {
        self.plans.store(stats.plans, Ordering::Relaxed);
        self.planned_steps.store(stats.planned_steps, Ordering::Relaxed);
        self.released_steps.store(stats.released_steps, Ordering::Relaxed);
        self.blocked_steps.store(stats.blocked_steps, Ordering::Relaxed);
        self.aborted_plans.store(stats.aborted_plans, Ordering::Relaxed);
        self.surrogate_scored.store(stats.surrogate_scored, Ordering::Relaxed);
        self.whatif_evals.store(stats.whatif_evals, Ordering::Relaxed);
        self.forced_explorations.store(stats.forced_explorations, Ordering::Relaxed);
    }

    /// The last mirrored planner snapshot.
    pub fn reallocation(&self) -> ReallocationStats {
        ReallocationStats {
            plans: self.plans.load(Ordering::Relaxed),
            planned_steps: self.planned_steps.load(Ordering::Relaxed),
            released_steps: self.released_steps.load(Ordering::Relaxed),
            blocked_steps: self.blocked_steps.load(Ordering::Relaxed),
            aborted_plans: self.aborted_plans.load(Ordering::Relaxed),
            surrogate_scored: self.surrogate_scored.load(Ordering::Relaxed),
            whatif_evals: self.whatif_evals.load(Ordering::Relaxed),
            forced_explorations: self.forced_explorations.load(Ordering::Relaxed),
        }
    }

    /// Record a worker crash (panic or heartbeat death) observed by the
    /// supervisor. Deduplicated upstream: one per instance death.
    pub fn on_crash(&self) {
        self.crashes.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request terminally failed by worker loss (recovery
    /// exhausted or no same-kind sibling left).
    pub fn on_request_lost(&self) {
        self.requests_lost.fetch_add(1, Ordering::Relaxed);
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one swept or errored work item re-dispatched to a sibling.
    pub fn on_request_retried(&self) {
        self.requests_retried.fetch_add(1, Ordering::Relaxed);
    }

    /// Record one decode-side work item re-targeted after a crash (the
    /// engine analogue of the simulator's streamed-PD re-reservation).
    pub fn on_request_retargeted(&self) {
        self.requests_retargeted.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request cancelled by its `deadline_ms` (504).
    pub fn on_deadline_exceeded(&self) {
        self.deadline_exceeded.fetch_add(1, Ordering::Relaxed);
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a per-request fall-back from a streamed handoff to the
    /// monolithic path (graceful degradation, not a failure).
    pub fn on_degraded_fallback(&self) {
        self.degraded_fallbacks.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a request failed because the drain bound elapsed.
    pub fn on_drain_failed(&self) {
        self.drain_failed.fetch_add(1, Ordering::Relaxed);
        self.failed.fetch_add(1, Ordering::Relaxed);
    }

    /// Mirror the supervisor's `HealthTracker` counters (monitor thread,
    /// once per supervise tick — store semantics like
    /// [`MetricsRecorder::record_reallocation`]).
    pub fn record_health(&self, h: &HealthStats) {
        self.breaker_opens.store(h.breaker_opens, Ordering::Relaxed);
        self.breaker_probes.store(h.breaker_probes, Ordering::Relaxed);
        self.quarantines.store(h.quarantines, Ordering::Relaxed);
    }

    /// Record one duplicate dispatch issued for a slow in-flight request.
    pub fn on_hedge_issued(&self) {
        self.hedges_issued.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a hedge whose duplicate leg completed first.
    pub fn on_hedge_won(&self) {
        self.hedges_won.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a hedge copy cancelled after the other leg completed.
    pub fn on_hedge_cancelled(&self) {
        self.hedges_cancelled.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a redispatch converted to a typed shed by the exhausted
    /// cluster retry budget.
    pub fn on_retry_budget_exhausted(&self) {
        self.retry_budget_exhausted.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of the schema shared with the simulator's
    /// `ResilienceStats` (one struct, one field list — they cannot drift).
    pub fn resilience_counters(&self) -> ResilienceCounters {
        ResilienceCounters {
            crashes: self.crashes.load(Ordering::Relaxed),
            requests_lost: self.requests_lost.load(Ordering::Relaxed),
            requests_retried: self.requests_retried.load(Ordering::Relaxed),
            requests_retargeted: self.requests_retargeted.load(Ordering::Relaxed),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_probes: self.breaker_probes.load(Ordering::Relaxed),
            quarantines: self.quarantines.load(Ordering::Relaxed),
            hedges_issued: self.hedges_issued.load(Ordering::Relaxed),
            hedges_won: self.hedges_won.load(Ordering::Relaxed),
            hedges_cancelled: self.hedges_cancelled.load(Ordering::Relaxed),
            retry_budget_exhausted: self.retry_budget_exhausted.load(Ordering::Relaxed),
        }
    }

    pub fn crashes(&self) -> u64 {
        self.crashes.load(Ordering::Relaxed)
    }

    pub fn requests_lost(&self) -> u64 {
        self.requests_lost.load(Ordering::Relaxed)
    }

    pub fn requests_retried(&self) -> u64 {
        self.requests_retried.load(Ordering::Relaxed)
    }

    pub fn requests_retargeted(&self) -> u64 {
        self.requests_retargeted.load(Ordering::Relaxed)
    }

    pub fn deadline_exceeded(&self) -> u64 {
        self.deadline_exceeded.load(Ordering::Relaxed)
    }

    pub fn degraded_fallbacks(&self) -> u64 {
        self.degraded_fallbacks.load(Ordering::Relaxed)
    }

    pub fn drain_failed(&self) -> u64 {
        self.drain_failed.load(Ordering::Relaxed)
    }

    /// Requests that terminated with a typed failure. Together with
    /// [`MetricsRecorder::finished`], the termination ledger:
    /// `finished + failed == submitted` once the engine is idle.
    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn on_arrival(&self, id: RequestId) {
        self.inner.lock().unwrap().push((
            id,
            Record { arrival: Instant::now(), first_token: None, finish: None, output_tokens: 0 },
        ));
    }

    pub fn on_first_token(&self, id: RequestId) {
        let mut g = self.inner.lock().unwrap();
        if let Some((_, r)) = g.iter_mut().find(|(rid, _)| *rid == id) {
            if r.first_token.is_none() {
                r.first_token = Some(Instant::now());
            }
        }
    }

    pub fn on_finish(&self, id: RequestId, output_tokens: u32) {
        let mut g = self.inner.lock().unwrap();
        if let Some((_, r)) = g.iter_mut().find(|(rid, _)| *rid == id) {
            r.finish = Some(Instant::now());
            r.output_tokens = output_tokens;
        }
    }

    /// (ttfts, tpots, latencies) of finished requests, seconds.
    pub fn series(&self) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
        let g = self.inner.lock().unwrap();
        let mut ttfts = Vec::new();
        let mut tpots = Vec::new();
        let mut lats = Vec::new();
        for (_, r) in g.iter() {
            let (Some(ft), Some(fin)) = (r.first_token, r.finish) else { continue };
            let ttft = ft.duration_since(r.arrival).as_secs_f64();
            let lat = fin.duration_since(r.arrival).as_secs_f64();
            ttfts.push(ttft);
            lats.push(lat);
            if r.output_tokens > 1 {
                tpots.push(fin.duration_since(ft).as_secs_f64() / (r.output_tokens - 1) as f64);
            }
        }
        (ttfts, tpots, lats)
    }

    pub fn finished(&self) -> usize {
        self.inner
            .lock()
            .unwrap()
            .iter()
            .filter(|(_, r)| r.finish.is_some())
            .count()
    }

    pub fn submitted(&self) -> usize {
        self.inner.lock().unwrap().len()
    }

    /// SLO attainment over submitted requests.
    pub fn slo_attainment(&self, slo: Slo) -> f64 {
        let g = self.inner.lock().unwrap();
        if g.is_empty() {
            return 0.0;
        }
        let ok = g
            .iter()
            .filter(|(_, r)| {
                let (Some(ft), Some(fin)) = (r.first_token, r.finish) else { return false };
                let ttft = ft.duration_since(r.arrival).as_secs_f64();
                let tpot = if r.output_tokens > 1 {
                    fin.duration_since(ft).as_secs_f64() / (r.output_tokens - 1) as f64
                } else {
                    0.0
                };
                slo.attained(ttft, tpot)
            })
            .count();
        ok as f64 / g.len() as f64
    }

    /// JSON report (written by `/metrics` and the examples).
    pub fn report(&self) -> Json {
        let (ttfts, tpots, lats) = self.series();
        let s = |x: &Summary| {
            Json::obj(vec![
                ("mean", Json::num(x.mean)),
                ("p50", Json::num(x.p50)),
                ("p90", Json::num(x.p90)),
                ("p99", Json::num(x.p99)),
                ("max", Json::num(x.max)),
            ])
        };
        Json::obj(vec![
            ("submitted", Json::num(self.submitted() as f64)),
            ("finished", Json::num(self.finished() as f64)),
            ("failed", Json::num(self.failed() as f64)),
            ("ttft", s(&Summary::of(&ttfts))),
            ("tpot", s(&Summary::of(&tpots))),
            ("latency", s(&Summary::of(&lats))),
            (
                "encoder_cache",
                Json::obj(vec![
                    ("hits", Json::num(self.encoder_cache_hits() as f64)),
                    ("misses", Json::num(self.encoder_cache_misses() as f64)),
                    ("hit_rate", Json::num(self.encoder_cache_hit_rate())),
                ]),
            ),
            (
                "ep_streaming",
                Json::obj(vec![
                    ("chunks", Json::num(self.ep_chunks() as f64)),
                    ("streamed_requests", Json::num(self.ep_streamed_requests() as f64)),
                    (
                        "reassembled_requests",
                        Json::num(self.ep_reassembled_requests() as f64),
                    ),
                ]),
            ),
            (
                "pd_streaming",
                Json::obj(vec![
                    ("chunks", Json::num(self.pd_chunks() as f64)),
                    ("streamed_requests", Json::num(self.pd_streamed_requests() as f64)),
                    (
                        "reassembled_requests",
                        Json::num(self.pd_reassembled_requests() as f64),
                    ),
                ]),
            ),
            (
                "stage_busy_seconds",
                Json::obj(vec![
                    ("encode", Json::num(self.stage_busy_seconds(Stage::Encode))),
                    ("prefill", Json::num(self.stage_busy_seconds(Stage::Prefill))),
                    ("decode", Json::num(self.stage_busy_seconds(Stage::Decode))),
                ]),
            ),
            (
                "router",
                Json::obj(vec![
                    ("shed", Json::num(self.router_shed() as f64)),
                    ("degraded", Json::num(self.router_degraded() as f64)),
                ]),
            ),
            ("resilience", {
                // The shared schema first (one field list with the sim —
                // see metrics/resilience.rs), then the engine-only tails.
                let mut fields = self.resilience_counters().json_fields();
                fields.push(("deadline_exceeded", Json::num(self.deadline_exceeded() as f64)));
                fields.push(("degraded_fallbacks", Json::num(self.degraded_fallbacks() as f64)));
                fields.push(("drain_failed", Json::num(self.drain_failed() as f64)));
                Json::obj(fields)
            }),
            ("reallocation", {
                let r = self.reallocation();
                Json::obj(vec![
                    ("switches", Json::num(self.role_switches() as f64)),
                    ("plans", Json::num(r.plans as f64)),
                    ("planned_steps", Json::num(r.planned_steps as f64)),
                    ("released_steps", Json::num(r.released_steps as f64)),
                    ("blocked_steps", Json::num(r.blocked_steps as f64)),
                    ("aborted_plans", Json::num(r.aborted_plans as f64)),
                    ("surrogate_scored", Json::num(r.surrogate_scored as f64)),
                    ("whatif_evals", Json::num(r.whatif_evals as f64)),
                    ("forced_explorations", Json::num(r.forced_explorations as f64)),
                ])
            }),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_and_series() {
        let m = MetricsRecorder::new();
        m.on_arrival(1);
        m.on_first_token(1);
        m.on_finish(1, 5);
        m.on_arrival(2); // never finishes
        let (ttfts, tpots, lats) = m.series();
        assert_eq!(ttfts.len(), 1);
        assert_eq!(tpots.len(), 1);
        assert_eq!(lats.len(), 1);
        assert_eq!(m.finished(), 1);
        assert_eq!(m.submitted(), 2);
    }

    #[test]
    fn attainment_counts_unfinished_as_miss() {
        let m = MetricsRecorder::new();
        m.on_arrival(1);
        m.on_first_token(1);
        m.on_finish(1, 2);
        m.on_arrival(2);
        let att = m.slo_attainment(Slo::new(10.0, 10.0));
        assert!((att - 0.5).abs() < 1e-12);
    }

    #[test]
    fn duplicate_first_token_ignored() {
        let m = MetricsRecorder::new();
        m.on_arrival(1);
        m.on_first_token(1);
        std::thread::sleep(std::time::Duration::from_millis(2));
        m.on_first_token(1); // must not move the timestamp
        m.on_finish(1, 3);
        let (ttfts, _, _) = m.series();
        assert!(ttfts[0] < 0.002, "first timestamp kept");
    }

    #[test]
    fn report_shape() {
        let m = MetricsRecorder::new();
        m.on_arrival(7);
        m.on_first_token(7);
        m.on_finish(7, 4);
        let j = m.report();
        assert_eq!(j.get("finished").unwrap().as_u64(), Some(1));
        assert!(j.get("ttft").unwrap().get("mean").is_some());
        assert!(j.get("encoder_cache").unwrap().get("hit_rate").is_some());
        assert!(j.get("ep_streaming").unwrap().get("chunks").is_some());
        assert!(j.get("pd_streaming").unwrap().get("chunks").is_some());
    }

    #[test]
    fn ep_streaming_counters() {
        let m = MetricsRecorder::new();
        m.on_ep_streamed();
        m.on_ep_chunk();
        m.on_ep_chunk();
        m.on_ep_reassembled();
        assert_eq!(m.ep_streamed_requests(), 1);
        assert_eq!(m.ep_chunks(), 2);
        assert_eq!(m.ep_reassembled_requests(), 1);
    }

    #[test]
    fn pd_streaming_counters() {
        let m = MetricsRecorder::new();
        m.on_pd_streamed();
        for _ in 0..4 {
            m.on_pd_chunk();
        }
        m.on_pd_reassembled();
        assert_eq!(m.pd_streamed_requests(), 1);
        assert_eq!(m.pd_chunks(), 4);
        assert_eq!(m.pd_reassembled_requests(), 1);
    }

    #[test]
    fn stage_work_and_shape_counters() {
        let m = MetricsRecorder::new();
        m.on_stage_work(Stage::Decode, 0.5, 4);
        m.on_stage_work(Stage::Decode, 0.25, 2);
        m.on_stage_work(Stage::Encode, 1.0, 1);
        assert!((m.stage_busy_seconds(Stage::Decode) - 0.75).abs() < 1e-6);
        assert_eq!(m.stage_jobs(Stage::Decode), 6);
        assert_eq!(m.stage_jobs(Stage::Prefill), 0);
        m.on_request_shape(4, 22, 10);
        m.on_request_shape(0, 64, 200);
        assert_eq!(m.request_shape_totals(), (4, 86, 210));
    }

    #[test]
    fn reallocation_snapshot_roundtrips() {
        let m = MetricsRecorder::new();
        assert_eq!(m.reallocation(), ReallocationStats::default());
        let s = ReallocationStats {
            plans: 3,
            planned_steps: 5,
            released_steps: 4,
            blocked_steps: 2,
            aborted_plans: 1,
            surrogate_scored: 40,
            whatif_evals: 6,
            forced_explorations: 2,
        };
        m.record_reallocation(s);
        m.on_role_switch();
        assert_eq!(m.reallocation(), s);
        assert_eq!(m.role_switches(), 1);
        let j = m.report();
        assert_eq!(j.get("reallocation").unwrap().get("plans").unwrap().as_u64(), Some(3));
        assert_eq!(
            j.get("reallocation").unwrap().get("surrogate_scored").unwrap().as_u64(),
            Some(40)
        );
        assert_eq!(
            j.get("reallocation").unwrap().get("whatif_evals").unwrap().as_u64(),
            Some(6)
        );
        assert!(j.get("stage_busy_seconds").unwrap().get("decode").is_some());
    }

    #[test]
    fn resilience_counters_and_report() {
        let m = MetricsRecorder::new();
        m.on_crash();
        m.on_request_retried();
        m.on_request_retried();
        m.on_request_retargeted();
        m.on_request_lost();
        m.on_deadline_exceeded();
        m.on_drain_failed();
        m.on_degraded_fallback();
        assert_eq!(m.crashes(), 1);
        assert_eq!(m.requests_retried(), 2);
        assert_eq!(m.requests_retargeted(), 1);
        assert_eq!(m.requests_lost(), 1);
        assert_eq!(m.deadline_exceeded(), 1);
        assert_eq!(m.drain_failed(), 1);
        assert_eq!(m.degraded_fallbacks(), 1);
        // Each terminal failure kind bumps the ledger total once.
        assert_eq!(m.failed(), 3);
        let j = m.report();
        assert_eq!(j.get("failed").unwrap().as_u64(), Some(3));
        let r = j.get("resilience").unwrap();
        assert_eq!(r.get("crashes").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("requests_retried").unwrap().as_u64(), Some(2));
        assert_eq!(r.get("requests_retargeted").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("requests_lost").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("deadline_exceeded").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("degraded_fallbacks").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("drain_failed").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn health_counters_share_the_sim_schema() {
        let m = MetricsRecorder::new();
        m.record_health(&HealthStats { breaker_opens: 2, quarantines: 1, breaker_probes: 5 });
        m.on_hedge_issued();
        m.on_hedge_won();
        m.on_hedge_cancelled();
        m.on_retry_budget_exhausted();
        let c = m.resilience_counters();
        assert_eq!(c.breaker_opens, 2);
        assert_eq!(c.breaker_probes, 5);
        assert_eq!(c.quarantines, 1);
        assert_eq!(c.hedges_issued, 1);
        assert_eq!(c.hedges_won, 1);
        assert_eq!(c.hedges_cancelled, 1);
        assert_eq!(c.retry_budget_exhausted, 1);
        // record_health is a mirror: re-recording stores, not adds.
        m.record_health(&HealthStats { breaker_opens: 3, quarantines: 1, breaker_probes: 5 });
        assert_eq!(m.resilience_counters().breaker_opens, 3);
        // /metrics exposes every shared field.
        let j = m.report();
        let r = j.get("resilience").unwrap();
        assert_eq!(r.get("breaker_opens").unwrap().as_u64(), Some(3));
        assert_eq!(r.get("quarantines").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("hedges_issued").unwrap().as_u64(), Some(1));
        assert_eq!(r.get("retry_budget_exhausted").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn encoder_cache_counters() {
        let m = MetricsRecorder::new();
        assert_eq!(m.encoder_cache_hit_rate(), 0.0);
        m.on_encoder_cache(false);
        m.on_encoder_cache(true);
        m.on_encoder_cache(true);
        assert_eq!(m.encoder_cache_hits(), 2);
        assert_eq!(m.encoder_cache_misses(), 1);
        assert!((m.encoder_cache_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
    }
}
