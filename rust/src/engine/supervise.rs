//! Engine supervision & recovery: deterministic fault injection
//! ([`EngineFaultPlan`], mirroring `sim/fault.rs`'s plan/builder
//! vocabulary), per-instance heartbeats and crash events, the per-request
//! ownership ledger behind exactly-once redispatch, retry backoff, the
//! deadline watchdog, and drain bookkeeping.
//!
//! Everything here is dormant by default: with `EpdConfig::supervise`
//! off and no fault plan armed, claims are no-ops, the watchdog holds no
//! requests, and the engine is bit-for-bit identical to the
//! pre-supervision behavior (property-tested in
//! `rust/tests/property_engine_faults.rs`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, Weak};
use std::time::{Duration, Instant};

use log::warn;

use crate::core::config::EpdConfig;
use crate::core::stage::Stage;
use crate::core::topology::DeploymentMode;
use crate::metrics::recorder::MetricsRecorder;
use crate::router::health::{HealthConfig, HealthStats, HealthTracker, RetryBudget};
use crate::util::rng::Rng;

use super::instance::pull_stages;
use super::job::{FailReason, GenFailure, GenResponse, Job, ReqCtx};
use super::queues::StageQueues;

/// Jitter stream for retry backoff when no fault seed is armed.
const DEFAULT_JITTER_SEED: u64 = 0x5EED_CAFE;

/// Lock a mutex, recovering the guard from a poisoned lock. A panicking
/// worker is a *crash event* under supervision, not a reason to cascade
/// panics through every thread that shares the fabric.
pub fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A seeded worker kill: the instance panics when it picks up its next
/// EP/decode work after completing `after_jobs` jobs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KillFault {
    pub instance: usize,
    pub after_jobs: u64,
}

/// A slow-worker (straggler) injection: every popped job on the instance
/// is delayed by `delay_ms` before execution.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlowFault {
    pub instance: usize,
    pub delay_ms: u64,
}

/// One injected streamed-handoff error: the instance's next streamed
/// EP/PD emission after `after_jobs` jobs fails, degrading that request
/// to the monolithic path.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HandoffFault {
    pub instance: usize,
    pub after_jobs: u64,
}

/// Deterministic engine-side fault plan (the engine analogue of
/// `sim::fault::FaultPlan`): seeded worker kills, handoff errors, and
/// slow workers, resolved to per-instance injection points at engine
/// start. Default is empty — bit-for-bit dormant.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct EngineFaultPlan {
    pub seed: u64,
    pub kills: Vec<KillFault>,
    pub slows: Vec<SlowFault>,
    pub handoffs: Vec<HandoffFault>,
}

impl EngineFaultPlan {
    /// The empty (dormant) plan.
    pub fn none() -> EngineFaultPlan {
        EngineFaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.kills.is_empty() && self.slows.is_empty() && self.handoffs.is_empty()
    }

    pub fn with_kill(mut self, instance: usize, after_jobs: u64) -> EngineFaultPlan {
        self.kills.push(KillFault { instance, after_jobs });
        self
    }

    pub fn with_slow(mut self, instance: usize, delay_ms: u64) -> EngineFaultPlan {
        self.slows.push(SlowFault { instance, delay_ms });
        self
    }

    pub fn with_handoff_error(mut self, instance: usize, after_jobs: u64) -> EngineFaultPlan {
        self.handoffs.push(HandoffFault { instance, after_jobs });
        self
    }

    /// Seeded kill wave over `instances` workers: a shuffled subset of
    /// `kills` instances (never all of them — recovery needs at least one
    /// survivor) dies, staggered one job apart starting at `after_jobs`.
    /// Seed 0 disarms the wave.
    pub fn wave(seed: u64, instances: usize, kills: u32, after_jobs: u64) -> EngineFaultPlan {
        let mut plan = EngineFaultPlan { seed, ..EngineFaultPlan::default() };
        if seed == 0 || instances == 0 {
            return plan;
        }
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..instances).collect();
        rng.shuffle(&mut order);
        let n_kills = (kills as usize).min(instances.saturating_sub(1));
        for (k, &idx) in order.iter().take(n_kills).enumerate() {
            plan = plan.with_kill(idx, after_jobs + k as u64);
        }
        plan
    }

    /// Resolve the plan from `EpdConfig::engine_fault_*`. Seed 0 (the
    /// default) yields the empty plan; slow and handoff injections land
    /// on the shuffled instances after the killed ones.
    pub fn from_epd(epd: &EpdConfig) -> EngineFaultPlan {
        let n = epd.instances.len();
        if epd.engine_fault_seed == 0 || n == 0 {
            return EngineFaultPlan::none();
        }
        let mut plan =
            EngineFaultPlan::wave(epd.engine_fault_seed, n, epd.engine_fault_kills, epd.engine_fault_after_jobs);
        let mut rng = Rng::new(epd.engine_fault_seed);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let killed = plan.kills.len();
        if epd.engine_fault_slow_ms > 0 {
            plan = plan.with_slow(order[killed % n], epd.engine_fault_slow_ms);
        }
        for h in 0..epd.engine_fault_handoff_errors as usize {
            plan = plan.with_handoff_error(order[(killed + h) % n], epd.engine_fault_after_jobs);
        }
        plan
    }

    /// Drop faults aimed at instances that don't exist.
    pub fn clamp_instances(mut self, n: usize) -> EngineFaultPlan {
        self.kills.retain(|f| f.instance < n);
        self.slows.retain(|f| f.instance < n);
        self.handoffs.retain(|f| f.instance < n);
        self
    }

    /// Job count after which `instance` is killed (min across entries).
    pub fn kill_after(&self, instance: usize) -> Option<u64> {
        self.kills.iter().filter(|f| f.instance == instance).map(|f| f.after_jobs).min()
    }

    /// Per-job delay for `instance` (max across entries; 0 = none).
    pub fn slow_ms(&self, instance: usize) -> u64 {
        self.slows.iter().filter(|f| f.instance == instance).map(|f| f.delay_ms).max().unwrap_or(0)
    }

    /// Handoff-error thresholds for `instance` (one injected error each).
    pub fn handoff_after(&self, instance: usize) -> Vec<u64> {
        self.handoffs.iter().filter(|f| f.instance == instance).map(|f| f.after_jobs).collect()
    }
}

/// A structured crash event, produced when a worker thread panics, fails
/// to initialize, or misses its heartbeat.
#[derive(Debug, Clone)]
pub struct CrashEvent {
    pub instance: usize,
    pub reason: String,
}

struct LedgerEntry {
    instance: usize,
    job: Job,
}

#[derive(Default)]
struct LedgerInner {
    next: u64,
    entries: HashMap<u64, LedgerEntry>,
}

/// Per-request ownership ledger: every job an instance is executing is
/// claimed here, so a dead instance's in-flight work can be swept and
/// re-dispatched to a same-kind sibling exactly once. Tokens are
/// process-unique; `None` tokens (supervision off) make every operation
/// a no-op.
#[derive(Default)]
pub struct InflightLedger {
    inner: Mutex<LedgerInner>,
}

impl InflightLedger {
    /// Record that `instance` is executing `job`; returns the claim token.
    pub fn claim(&self, instance: usize, job: Job) -> u64 {
        let mut g = lock_clean(&self.inner);
        g.next += 1;
        let token = g.next;
        g.entries.insert(token, LedgerEntry { instance, job });
        token
    }

    /// Replace a claim's job snapshot (e.g. a reassembled chunk promoted
    /// to its merged job) so a crash replays the *current* work, not a
    /// stage the request already passed.
    pub fn update(&self, token: Option<u64>, job: Job) {
        if let Some(t) = token {
            let mut g = lock_clean(&self.inner);
            if let Some(e) = g.entries.get_mut(&t) {
                e.job = job;
            }
        }
    }

    /// Drop a claim (the job completed or was handed off).
    pub fn release(&self, token: Option<u64>) {
        if let Some(t) = token {
            lock_clean(&self.inner).entries.remove(&t);
        }
    }

    /// Remove and return a claim's job snapshot (the failure path: the
    /// caller decides between retry and terminal failure).
    pub fn take(&self, token: Option<u64>) -> Option<Job> {
        let t = token?;
        lock_clean(&self.inner).entries.remove(&t).map(|e| e.job)
    }

    /// Remove and return every job claimed by a (dead) instance.
    pub fn sweep_instance(&self, instance: usize) -> Vec<Job> {
        let mut g = lock_clean(&self.inner);
        let tokens: Vec<u64> = g
            .entries
            .iter()
            .filter(|(_, e)| e.instance == instance)
            .map(|(&t, _)| t)
            .collect();
        tokens.into_iter().filter_map(|t| g.entries.remove(&t)).map(|e| e.job).collect()
    }

    pub fn len(&self) -> usize {
        lock_clean(&self.inner).entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

struct RetryItem {
    due: Instant,
    job: Job,
}

/// The supervision state shared through [`StageQueues`]: heartbeats,
/// liveness, crash events, the ownership ledger, the delayed-retry queue,
/// the deadline watchdog registry, and the drain flag.
pub struct Supervision {
    enabled: bool,
    pub heartbeat_ms: u64,
    pub grace_ms: u64,
    pub retry_limit: u32,
    pub retry_base_ms: u64,
    jitter_seed: u64,
    track_requests: bool,
    t0: Instant,
    /// Last heartbeat per instance, ms since `t0`.
    beats: Vec<AtomicU64>,
    alive: Vec<AtomicBool>,
    crashes: Mutex<Vec<CrashEvent>>,
    pub ledger: InflightLedger,
    retries: Mutex<Vec<RetryItem>>,
    watch: Mutex<Vec<Weak<ReqCtx>>>,
    draining: AtomicBool,
    /// Per-instance circuit breakers (`health_breaker = on`): fed by
    /// crash events, consulted at typed-submit admission. `None` at
    /// defaults — the health layer is bit-for-bit absent.
    health: Option<Mutex<HealthTracker>>,
    /// Cluster-wide redispatch token bucket (`retry_budget_per_s > 0`):
    /// crash sweeps and worker-failure retries past the budget degrade
    /// to typed sheds instead of a retry storm.
    retry_budget: Option<Mutex<RetryBudget>>,
}

impl Supervision {
    /// Supervision off: every claim/track/scan is a no-op. This is the
    /// default wiring (`EpdConfig::supervise = false`).
    pub fn disabled(instances: usize) -> Supervision {
        Supervision {
            enabled: false,
            heartbeat_ms: 0,
            grace_ms: 0,
            retry_limit: 0,
            retry_base_ms: 1,
            jitter_seed: DEFAULT_JITTER_SEED,
            track_requests: false,
            t0: Instant::now(),
            beats: (0..instances).map(|_| AtomicU64::new(0)).collect(),
            alive: (0..instances).map(|_| AtomicBool::new(true)).collect(),
            crashes: Mutex::new(Vec::new()),
            ledger: InflightLedger::default(),
            retries: Mutex::new(Vec::new()),
            watch: Mutex::new(Vec::new()),
            draining: AtomicBool::new(false),
            health: None,
            retry_budget: None,
        }
    }

    /// Resolve from `EpdConfig::{supervise, supervise_heartbeat_ms,
    /// supervise_grace_ms, retry_limit, retry_base_ms, drain_timeout_ms,
    /// engine_fault_seed}`.
    pub fn from_epd(epd: &EpdConfig, instances: usize) -> Supervision {
        let mut s = Supervision::disabled(instances);
        s.enabled = epd.supervise;
        s.heartbeat_ms = epd.supervise_heartbeat_ms;
        s.grace_ms = epd.supervise_grace_ms;
        s.retry_limit = epd.retry_limit;
        s.retry_base_ms = epd.retry_base_ms.max(1);
        s.track_requests = epd.supervise || epd.drain_timeout_ms > 0;
        if epd.engine_fault_seed != 0 {
            s.jitter_seed = epd.engine_fault_seed;
        }
        // Same gating as the simulator: the health layer resolves to
        // nothing at defaults (no tracker, no bucket).
        let health_cfg = HealthConfig::from_epd(epd);
        s.health = health_cfg
            .filter(|hc| hc.breaker)
            .map(|hc| Mutex::new(HealthTracker::new(hc, instances)));
        s.retry_budget = health_cfg
            .filter(|hc| hc.retry_budget_per_s > 0.0)
            .map(|hc| Mutex::new(RetryBudget::new(hc.retry_budget_per_s, hc.retry_budget_burst)));
        s
    }

    /// Whether active recovery (claims, heartbeat scans, watchdog) is on.
    pub fn active(&self) -> bool {
        self.enabled
    }

    fn now_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }

    /// Record a liveness heartbeat for `instance`.
    pub fn beat(&self, instance: usize) {
        if let Some(b) = self.beats.get(instance) {
            b.store(self.now_ms(), Ordering::Relaxed);
        }
    }

    pub fn is_alive(&self, instance: usize) -> bool {
        self.alive.get(instance).map_or(true, |a| a.load(Ordering::SeqCst))
    }

    pub fn alive_count(&self) -> usize {
        self.alive.iter().filter(|a| a.load(Ordering::SeqCst)).count()
    }

    pub fn any_dead(&self) -> bool {
        self.alive_count() < self.alive.len()
    }

    /// Mark `instance` dead; returns whether it was alive (first death).
    pub fn mark_dead(&self, instance: usize) -> bool {
        self.alive.get(instance).is_some_and(|a| a.swap(false, Ordering::SeqCst))
    }

    /// Convert a worker death into a structured crash event. Idempotent
    /// per instance: only the first death produces an event (returns
    /// true); a heartbeat timeout followed by the panic landing, or vice
    /// versa, counts once.
    pub fn on_crash(&self, instance: usize, reason: &str) -> bool {
        if !self.mark_dead(instance) {
            return false;
        }
        warn!("instance {instance} crashed: {reason}");
        // Feed the breaker: the instance opens (and a flapper
        // quarantines) the moment its death is recorded.
        if let Some(h) = &self.health {
            let now = self.now_ms() as f64 / 1000.0;
            lock_clean(h).on_failure(now, instance);
        }
        lock_clean(&self.crashes)
            .push(CrashEvent { instance, reason: reason.to_string() });
        true
    }

    /// Whether the breaker layer is configured (`health_breaker = on`).
    pub fn health_active(&self) -> bool {
        self.health.is_some()
    }

    /// Breaker admission check for `instance`: `true` with no breaker
    /// configured; otherwise consumes a Half-Open probe like any
    /// dispatch offer would.
    pub fn health_admits(&self, instance: usize) -> bool {
        match &self.health {
            Some(h) => {
                let now = self.now_ms() as f64 / 1000.0;
                lock_clean(h).admits(now, instance)
            }
            None => true,
        }
    }

    /// Snapshot of the breaker counters for the `/metrics` mirror.
    pub fn health_stats(&self) -> Option<HealthStats> {
        self.health.as_ref().map(|h| lock_clean(h).stats)
    }

    /// One redispatch token, or `true` unconditionally when no retry
    /// budget is configured.
    pub fn budget_allows(&self) -> bool {
        match &self.retry_budget {
            Some(b) => {
                let now = self.now_ms() as f64 / 1000.0;
                lock_clean(b).try_take(now)
            }
            None => true,
        }
    }

    /// Drain pending crash events (the supervisor tick owns recovery).
    pub fn take_crashes(&self) -> Vec<CrashEvent> {
        std::mem::take(&mut *lock_clean(&self.crashes))
    }

    /// Alive instances whose last heartbeat is older than
    /// `supervise_heartbeat_ms` (empty when supervision is off).
    pub fn stale_instances(&self) -> Vec<usize> {
        if !self.enabled || self.heartbeat_ms == 0 {
            return Vec::new();
        }
        let now = self.now_ms();
        self.beats
            .iter()
            .enumerate()
            .filter(|(i, b)| {
                self.is_alive(*i) && now.saturating_sub(b.load(Ordering::Relaxed)) > self.heartbeat_ms
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Claim ownership of `job` for `instance`; `None` when supervision
    /// is off (claims would be bookkeeping nobody sweeps).
    pub fn claim(&self, instance: usize, job: &Job) -> Option<u64> {
        if !self.enabled {
            return None;
        }
        Some(self.ledger.claim(instance, job.clone()))
    }

    pub fn release(&self, token: Option<u64>) {
        self.ledger.release(token);
    }

    /// Deterministic exponential backoff for attempt `attempt` (1-based)
    /// of request `id`: `retry_base_ms << (attempt-1)` plus seeded jitter
    /// below `retry_base_ms` — a pure function of (seed, id, attempt).
    pub fn backoff_ms(&self, id: u64, attempt: u32) -> u64 {
        let base = self.retry_base_ms.max(1);
        let shift = attempt.saturating_sub(1).min(6);
        let jitter =
            Rng::new(self.jitter_seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ attempt as u64)
                .below(base);
        base.saturating_mul(1u64 << shift) + jitter
    }

    /// Queue `job` for redispatch after the attempt's backoff delay.
    pub fn schedule_retry(&self, job: Job, attempt: u32) {
        let delay = self.backoff_ms(job.ctx().id, attempt);
        lock_clean(&self.retries)
            .push(RetryItem { due: Instant::now() + Duration::from_millis(delay), job });
    }

    /// Take every retry whose backoff has elapsed.
    pub fn due_retries(&self) -> Vec<Job> {
        let mut q = lock_clean(&self.retries);
        let now = Instant::now();
        let mut due = Vec::new();
        let mut i = 0;
        while i < q.len() {
            if q[i].due <= now {
                due.push(q.swap_remove(i).job);
            } else {
                i += 1;
            }
        }
        due
    }

    pub fn retries_pending(&self) -> usize {
        lock_clean(&self.retries).len()
    }

    /// Register a request with the deadline watchdog / drain registry.
    pub fn track(&self, ctx: &Arc<ReqCtx>) {
        if self.track_requests {
            lock_clean(&self.watch).push(Arc::downgrade(ctx));
        }
    }

    /// Requests past `deadline + grace` that have not yet terminated.
    /// Terminated and dropped entries are pruned as a side effect.
    pub fn expired_watches(&self) -> Vec<Arc<ReqCtx>> {
        let mut expired = Vec::new();
        let mut w = lock_clean(&self.watch);
        w.retain(|weak| match weak.upgrade() {
            Some(ctx) => {
                if ctx.is_terminated() {
                    return false;
                }
                if ctx.past_deadline_with_grace(self.grace_ms) {
                    expired.push(ctx);
                    return false;
                }
                true
            }
            None => false,
        });
        expired
    }

    /// Every live (unterminated) tracked request — the drain fail-all set.
    pub fn live_requests(&self) -> Vec<Arc<ReqCtx>> {
        let mut live = Vec::new();
        let mut w = lock_clean(&self.watch);
        w.retain(|weak| match weak.upgrade() {
            Some(ctx) => {
                if ctx.is_terminated() {
                    return false;
                }
                live.push(Arc::clone(&ctx));
                true
            }
            None => false,
        });
        live
    }

    /// Stop intake: new submits are refused with a structured error.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }
}

/// Terminate a request with a typed failure. Exactly-once by the
/// terminated CAS: if the request already finished or failed, this is a
/// no-op (no double send, no double count).
pub fn fail_request(ctx: &Arc<ReqCtx>, reason: FailReason, metrics: &MetricsRecorder) {
    if !ctx.try_terminate() {
        return;
    }
    match &reason {
        FailReason::WorkerLost | FailReason::Runtime(_) => metrics.on_request_lost(),
        FailReason::DeadlineExceeded => metrics.on_deadline_exceeded(),
        FailReason::Draining => metrics.on_drain_failed(),
    }
    let failure = GenFailure {
        id: ctx.id,
        reason,
        retries: ctx.retry_count(),
        latency: ctx.arrival.elapsed().as_secs_f64(),
    };
    warn!("request {} failed: {}", ctx.id, failure.reason.code());
    // Receiver may have gone away (fire-and-forget submits) — ignore.
    let _ = ctx.done_tx.try_send(GenResponse::Failed(failure));
}

/// [`fail_request`] plus fabric cleanup: cancel the request's remaining
/// queued jobs (stage boundaries skip cancelled work) and drop its
/// partial reassembly state from both streamed edges.
pub fn fail_and_clean(
    queues: &StageQueues,
    ctx: &Arc<ReqCtx>,
    reason: FailReason,
    metrics: &MetricsRecorder,
) {
    ctx.cancel();
    queues.reassembly.abort(ctx.id);
    queues.kv_reassembly.abort(ctx.id);
    fail_request(ctx, reason, metrics);
}

/// Failure path for an owned job: retry from the ledger snapshot while
/// the request has budget, otherwise fail it terminally. With
/// supervision off the token is `None` and the request fails immediately
/// (typed — never a silent drop).
pub fn recover_or_fail(
    queues: &StageQueues,
    metrics: &MetricsRecorder,
    token: Option<u64>,
    ctx: &Arc<ReqCtx>,
    what: &str,
) {
    let sup = &queues.supervision;
    if let Some(job) = sup.ledger.take(token) {
        if sup.active() && ctx.retry_count() < sup.retry_limit {
            if !sup.budget_allows() {
                // Cluster retry budget exhausted: the failure degrades to
                // a typed shed instead of another redispatch.
                metrics.on_retry_budget_exhausted();
                fail_and_clean(queues, ctx, FailReason::Runtime(what.to_string()), metrics);
                return;
            }
            let attempt = ctx.note_retry();
            metrics.on_request_retried();
            sup.schedule_retry(job, attempt);
            return;
        }
    }
    fail_and_clean(queues, ctx, FailReason::Runtime(what.to_string()), metrics);
}

/// Whether any alive instance pulls `stage` under `mode` — a swept job
/// only retries if a same-kind sibling exists to execute it.
fn stage_covered(queues: &StageQueues, mode: DeploymentMode, stage: Stage) -> bool {
    let roles = queues.roles_snapshot();
    roles
        .iter()
        .enumerate()
        .any(|(i, &r)| queues.supervision.is_alive(i) && pull_stages(mode, r).contains(&stage))
}

/// [`stage_covered`] plus the circuit breaker: an alive instance whose
/// breaker refuses traffic (Open/Quarantined) does not count. The typed
/// submit path sheds new requests when a required stage has no healthy
/// instance left. Identical to [`stage_covered`] without
/// `health_breaker` — `health_admits` is then unconditionally true.
pub fn stage_has_healthy(queues: &StageQueues, mode: DeploymentMode, stage: Stage) -> bool {
    let roles = queues.roles_snapshot();
    roles.iter().enumerate().any(|(i, &r)| {
        queues.supervision.is_alive(i)
            && pull_stages(mode, r).contains(&stage)
            && queues.supervision.health_admits(i)
    })
}

/// One supervisor pass, run from the monitor loop (and from the drain
/// loop in `shutdown`): heartbeat scan → crash sweep & redispatch → due
/// retries → orphaned-queue evacuation → deadline watchdog. Returns the
/// number of crash events swept this pass, so the monitor can force an
/// out-of-band plan pass under `health_replan`.
pub fn supervise_tick(
    queues: &StageQueues,
    metrics: &MetricsRecorder,
    mode: DeploymentMode,
) -> usize {
    let sup = &queues.supervision;

    // 1. Heartbeat scan: silent workers become synthetic crash events.
    for idx in sup.stale_instances() {
        if sup.on_crash(idx, &format!("no heartbeat for {} ms", sup.heartbeat_ms)) {
            metrics.on_crash();
        }
    }

    // 2. Crash sweep: re-dispatch a dead instance's claimed work to a
    // same-kind sibling (exactly once — sweeping removes the claim).
    // Decode-side jobs count as re-targets (the engine analogue of the
    // simulator's reserved-stream `pd_retarget`), encode/prefill as
    // retries. Each redispatch consumes a cluster retry-budget token;
    // past the budget, the sweep degrades to typed sheds.
    let mut crashes = 0usize;
    for ev in sup.take_crashes() {
        crashes += 1;
        for job in sup.ledger.sweep_instance(ev.instance) {
            let ctx = Arc::clone(job.ctx());
            if ctx.is_terminated() || ctx.is_cancelled() {
                continue;
            }
            let stage = job.stage();
            if !stage_covered(queues, mode, stage) {
                fail_and_clean(queues, &ctx, FailReason::WorkerLost, metrics);
                continue;
            }
            if sup.active() && ctx.retry_count() < sup.retry_limit {
                if !sup.budget_allows() {
                    metrics.on_retry_budget_exhausted();
                    fail_and_clean(queues, &ctx, FailReason::WorkerLost, metrics);
                    continue;
                }
                let attempt = ctx.note_retry();
                if matches!(stage, Stage::Decode) {
                    metrics.on_request_retargeted();
                } else {
                    metrics.on_request_retried();
                }
                sup.schedule_retry(job, attempt);
            } else {
                fail_and_clean(queues, &ctx, FailReason::WorkerLost, metrics);
            }
        }
    }

    // 3. Push due retries back onto the fabric (a sibling pulls them).
    for job in sup.due_retries() {
        let ctx = Arc::clone(job.ctx());
        if ctx.is_terminated() || ctx.is_cancelled() {
            continue;
        }
        let stage = job.stage();
        if stage_covered(queues, mode, stage) {
            queues.push(stage, job);
        } else {
            fail_and_clean(queues, &ctx, FailReason::WorkerLost, metrics);
        }
    }

    // 4. Evacuate queues no alive instance serves: unclaimed jobs headed
    // for a dead stage would otherwise hang their receivers forever.
    if sup.active() && sup.any_dead() {
        for stage in Stage::ALL {
            if !stage_covered(queues, mode, stage) {
                while let Some(job) = queues.try_pop(&[stage]) {
                    let ctx = Arc::clone(job.ctx());
                    if !ctx.is_terminated() {
                        fail_and_clean(queues, &ctx, FailReason::WorkerLost, metrics);
                    }
                }
            }
        }
    }

    // 5. Deadline watchdog: no receiver blocks past `deadline + grace`,
    // even if every stage boundary was already passed.
    for ctx in sup.expired_watches() {
        fail_and_clean(queues, &ctx, FailReason::DeadlineExceeded, metrics);
    }

    // 6. Mirror the breaker counters into `/metrics` (store semantics —
    // absent entirely without `health_breaker`).
    if let Some(hs) = sup.health_stats() {
        metrics.record_health(&hs);
    }
    crashes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::topology::Topology;
    use std::sync::mpsc::sync_channel;

    fn ctx(id: u64) -> Arc<ReqCtx> {
        let (tx, _rx) = sync_channel(1);
        Arc::new(ReqCtx::new(id, 0, vec![], 4, None, 1, tx))
    }

    fn job(id: u64) -> Job {
        Job::Prefill { ctx: ctx(id), mm: Arc::new(vec![]) }
    }

    #[test]
    fn wave_is_deterministic_and_bounded() {
        let a = EngineFaultPlan::wave(7, 5, 2, 3);
        let b = EngineFaultPlan::wave(7, 5, 2, 3);
        assert_eq!(a, b);
        assert_eq!(a.kills.len(), 2);
        assert!(a.kills.iter().all(|k| k.instance < 5));
        // Never kills every instance.
        let all = EngineFaultPlan::wave(7, 3, 99, 0);
        assert_eq!(all.kills.len(), 2);
        // Seed 0 disarms.
        assert!(EngineFaultPlan::wave(0, 5, 2, 3).is_empty());
    }

    #[test]
    fn default_config_yields_dormant_plan() {
        let epd = EpdConfig::epd(Topology::new(1, 1, 1), 1, 1, 128);
        assert_eq!(epd.engine_fault_seed, 0);
        assert!(EngineFaultPlan::from_epd(&epd).is_empty());
    }

    #[test]
    fn plan_resolution_per_instance() {
        let plan = EngineFaultPlan::none()
            .with_kill(1, 4)
            .with_kill(1, 2)
            .with_slow(0, 9)
            .with_handoff_error(2, 1)
            .with_handoff_error(2, 5);
        assert_eq!(plan.kill_after(1), Some(2));
        assert_eq!(plan.kill_after(0), None);
        assert_eq!(plan.slow_ms(0), 9);
        assert_eq!(plan.slow_ms(1), 0);
        assert_eq!(plan.handoff_after(2), vec![1, 5]);
        let clamped = plan.clamp_instances(2);
        assert!(clamped.handoffs.is_empty());
        assert_eq!(clamped.kills.len(), 2);
    }

    #[test]
    fn ledger_claim_release_take_sweep() {
        let l = InflightLedger::default();
        let t1 = l.claim(0, job(1));
        let t2 = l.claim(0, job(2));
        let t3 = l.claim(1, job(3));
        assert_eq!(l.len(), 3);
        l.release(Some(t1));
        assert_eq!(l.len(), 2);
        let taken = l.take(Some(t2)).expect("claimed job");
        assert_eq!(taken.ctx().id, 2);
        assert!(l.take(Some(t2)).is_none(), "take is exactly-once");
        let swept = l.sweep_instance(0);
        assert!(swept.is_empty(), "instance 0 has no claims left");
        let swept = l.sweep_instance(1);
        assert_eq!(swept.len(), 1);
        assert_eq!(swept[0].ctx().id, 3);
        assert_eq!(l.len(), 0);
        let _ = t3;
    }

    #[test]
    fn disabled_supervision_claims_nothing() {
        let s = Supervision::disabled(2);
        assert!(!s.active());
        assert!(s.claim(0, &job(1)).is_none());
        assert!(s.ledger.is_empty());
        assert!(s.stale_instances().is_empty());
        s.track(&ctx(1));
        assert!(s.live_requests().is_empty(), "tracking off by default");
    }

    #[test]
    fn crash_events_dedupe_per_instance() {
        let s = Supervision::disabled(2);
        assert!(s.on_crash(0, "panic"));
        assert!(!s.on_crash(0, "heartbeat"), "second death is a no-op");
        assert!(!s.is_alive(0));
        assert!(s.is_alive(1));
        assert_eq!(s.take_crashes().len(), 1);
        assert!(s.take_crashes().is_empty());
        assert_eq!(s.alive_count(), 1);
        assert!(s.any_dead());
    }

    #[test]
    fn backoff_is_deterministic_and_exponential() {
        let epd = {
            let mut e = EpdConfig::epd(Topology::new(1, 1, 1), 1, 1, 128);
            e.supervise = true;
            e.retry_base_ms = 8;
            e
        };
        let s = Supervision::from_epd(&epd, 3);
        assert!(s.active());
        let a1 = s.backoff_ms(42, 1);
        assert_eq!(a1, s.backoff_ms(42, 1), "pure function of (id, attempt)");
        assert!((8..16).contains(&a1), "base + jitter below base: {a1}");
        let a3 = s.backoff_ms(42, 3);
        assert!((32..40).contains(&a3), "8 << 2 + jitter: {a3}");
    }

    #[test]
    fn heartbeat_staleness_detection() {
        let mut epd = EpdConfig::epd(Topology::new(1, 1, 1), 1, 1, 128);
        epd.supervise = true;
        epd.supervise_heartbeat_ms = 20;
        let s = Supervision::from_epd(&epd, 2);
        s.beat(0);
        s.beat(1);
        assert!(s.stale_instances().is_empty());
        std::thread::sleep(Duration::from_millis(40));
        s.beat(1);
        assert_eq!(s.stale_instances(), vec![0]);
    }

    #[test]
    fn retry_queue_respects_backoff() {
        let mut epd = EpdConfig::epd(Topology::new(1, 1, 1), 1, 1, 128);
        epd.supervise = true;
        epd.retry_base_ms = 30;
        let s = Supervision::from_epd(&epd, 1);
        s.schedule_retry(job(1), 1);
        assert_eq!(s.retries_pending(), 1);
        assert!(s.due_retries().is_empty(), "backoff not yet elapsed");
        std::thread::sleep(Duration::from_millis(70));
        assert_eq!(s.due_retries().len(), 1);
        assert_eq!(s.retries_pending(), 0);
    }

    #[test]
    fn fail_request_is_exactly_once() {
        let (tx, rx) = sync_channel(2);
        let c = Arc::new(ReqCtx::new(9, 0, vec![], 4, None, 1, tx));
        let m = MetricsRecorder::new();
        fail_request(&c, FailReason::WorkerLost, &m);
        fail_request(&c, FailReason::DeadlineExceeded, &m);
        let first = rx.try_recv().expect("one failure response");
        match first {
            GenResponse::Failed(f) => assert!(matches!(f.reason, FailReason::WorkerLost)),
            GenResponse::Done(_) => panic!("expected failure"),
        }
        assert!(rx.try_recv().is_err(), "second failure suppressed");
        assert_eq!(m.failed(), 1);
        assert_eq!(m.requests_lost(), 1);
        assert_eq!(m.deadline_exceeded(), 0);
    }

    #[test]
    fn watchdog_expires_past_deadline_plus_grace() {
        let mut epd = EpdConfig::epd(Topology::new(1, 1, 1), 1, 1, 128);
        epd.supervise = true;
        epd.supervise_grace_ms = 10;
        let s = Supervision::from_epd(&epd, 1);
        let (tx, _rx) = sync_channel(1);
        let c = Arc::new(ReqCtx::new(5, 0, vec![], 4, None, 1, tx).with_deadline_ms(15));
        s.track(&c);
        assert!(s.expired_watches().is_empty());
        std::thread::sleep(Duration::from_millis(40));
        let expired = s.expired_watches();
        assert_eq!(expired.len(), 1);
        assert_eq!(expired[0].id, 5);
        assert!(s.expired_watches().is_empty(), "expired entries pruned");
    }

    #[test]
    fn drain_flag() {
        let s = Supervision::disabled(1);
        assert!(!s.is_draining());
        s.begin_drain();
        assert!(s.is_draining());
    }
}
