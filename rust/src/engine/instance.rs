//! The instance thread: owns one `TinyLmmRuntime` ("its GPU"), pulls work
//! for its current role from the stage queues, and executes it. Handles
//! dynamic role switching via its control channel (§3.2.4: offload is
//! implicit — unprocessed work lives in the *global* queues, so a
//! switching instance simply stops pulling; migration is modelled by the
//! executable warm-up for the new role plus the configured pause).
//!
//! The thread body is wrapped in `catch_unwind`: a panic (real or
//! injected by the [`super::supervise::EngineFaultPlan`]) becomes a
//! structured crash event instead of a silent death, and every job the
//! instance owned at the time is swept from the ownership ledger and
//! re-dispatched by the supervisor.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Duration;

use log::{debug, info, warn};

use crate::core::stage::Stage;
use crate::core::topology::DeploymentMode;
use crate::metrics::recorder::MetricsRecorder;
use crate::model::tokenizer;
use crate::runtime::tiny_lmm::{argmax, TinyLmmRuntime};

use super::job::{FailReason, GenOutput, GenResponse, Job, ReqCtx};
use super::queues::StageQueues;
use super::serve::synth_patches;
use super::supervise::{fail_and_clean, lock_clean, recover_or_fail};

/// Control messages to an instance.
pub enum Ctrl {
    /// Switch role to the given stage after a simulated migration pause.
    Switch { to: Stage, pause: Duration },
    Shutdown,
}

/// Per-instance configuration.
pub struct InstanceParams {
    pub idx: usize,
    pub role: Stage,
    pub mode: DeploymentMode,
    pub artifacts_dir: String,
    /// Decode batch cap (bounded by the largest decode bucket).
    pub max_decode_batch: u32,
    /// Steps between queue re-checks inside a decode loop (monolith
    /// preemption granularity).
    pub decode_recheck_steps: u32,
    /// Layer groups for the streamed PD handoff: > 0 splits each
    /// prefilled KV into this many contiguous groups that transfer as
    /// individual [`Job::KvChunk`]s and reassemble decode-side; 0 ships
    /// the KV whole (monolithic handoff).
    pub pd_layer_groups: u32,
    /// Injected kill: panic when picking up work after this many
    /// completed jobs (`EngineFaultPlan::kill_after`). `None` = never.
    pub kill_after_jobs: Option<u64>,
    /// Injected straggler: delay every popped job by this many ms.
    pub fault_slow_ms: u64,
    /// Injected handoff errors: job-count thresholds, one streamed
    /// EP/PD emission failure each.
    pub fault_handoff_after: Vec<u64>,
}

/// Mutable per-thread fault-injection state, resolved from
/// [`InstanceParams`] at thread start. Dormant (all no-ops) when the
/// engine's fault plan is empty.
struct FaultState {
    kill_after: Option<u64>,
    slow_ms: u64,
    handoff_after: Vec<u64>,
    jobs_done: u64,
}

impl FaultState {
    fn from_params(p: &InstanceParams) -> FaultState {
        FaultState {
            kill_after: p.kill_after_jobs,
            slow_ms: p.fault_slow_ms,
            handoff_after: p.fault_handoff_after.clone(),
            jobs_done: 0,
        }
    }

    /// Injected worker kill: fires when picking up work past the
    /// threshold — *after* the job is claimed in the ledger, so the
    /// sweep always finds the stranded work.
    fn maybe_kill(&self) {
        if let Some(k) = self.kill_after {
            if self.jobs_done > k {
                panic!("injected worker kill (engine fault plan)");
            }
        }
    }

    /// Consume one injected handoff error if a threshold has passed.
    fn take_handoff(&mut self) -> bool {
        if let Some(pos) = self.handoff_after.iter().position(|&k| self.jobs_done > k) {
            self.handoff_after.swap_remove(pos);
            return true;
        }
        false
    }
}

/// Stage-pull priority for a role under a deployment mode.
pub fn pull_stages(mode: DeploymentMode, role: Stage) -> Vec<Stage> {
    match mode {
        DeploymentMode::Epd => vec![role],
        DeploymentMode::PdDisagg => match role {
            Stage::Encode | Stage::Prefill => vec![Stage::Encode, Stage::Prefill],
            Stage::Decode => vec![Stage::Decode],
        },
        // vLLM-like: EP work preempts decode.
        DeploymentMode::Aggregated => vec![Stage::Encode, Stage::Prefill, Stage::Decode],
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Thread body: the supervision boundary. Panics and initialization
/// failures become structured crash events; the supervisor sweeps the
/// dead instance's claimed work and re-dispatches it.
pub fn instance_main(
    params: InstanceParams,
    queues: Arc<StageQueues>,
    ctrl: Receiver<Ctrl>,
    metrics: Arc<MetricsRecorder>,
) {
    let idx = params.idx;
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        instance_run(params, &queues, &ctrl, &metrics)
    }));
    match outcome {
        Ok(Ok(())) => debug!("instance {idx} down"),
        Ok(Err(reason)) => {
            if queues.supervision.on_crash(idx, &reason) {
                metrics.on_crash();
            }
        }
        Err(payload) => {
            let reason = format!("panic: {}", panic_message(payload.as_ref()));
            if queues.supervision.on_crash(idx, &reason) {
                metrics.on_crash();
            }
        }
    }
}

fn instance_run(
    params: InstanceParams,
    queues: &Arc<StageQueues>,
    ctrl: &Receiver<Ctrl>,
    metrics: &Arc<MetricsRecorder>,
) -> Result<(), String> {
    queues.supervision.beat(params.idx);
    let mut rt = TinyLmmRuntime::load(&params.artifacts_dir)
        .map_err(|e| format!("runtime load failed: {e:#}"))?;
    let mut role = params.role;
    warm_for(&mut rt, params.mode, role).map_err(|e| format!("warm-up failed: {e:#}"))?;
    info!("instance {} up as {role}", params.idx);
    let mut faults = FaultState::from_params(&params);

    loop {
        queues.supervision.beat(params.idx);
        // Control first: switches and shutdown preempt new work.
        match ctrl.try_recv() {
            Ok(Ctrl::Shutdown) => break,
            Ok(Ctrl::Switch { to, pause }) => {
                info!("instance {}: switching {role} -> {to}", params.idx);
                // Migration (§3.2.4): reconfigure model + caches. Weight
                // upload for the new role is real work; the pause models
                // the remainder of the paper's measured switch time.
                std::thread::sleep(pause);
                if let Err(e) = warm_for(&mut rt, params.mode, to) {
                    warn!("instance {}: warm failed on switch: {e:#}", params.idx);
                }
                role = to;
                queues.set_role(params.idx, to);
                continue;
            }
            Err(_) => {}
        }
        if queues.is_shutdown() {
            break;
        }

        let stages = pull_stages(params.mode, role);
        // Decode work is batch-formed separately.
        let non_decode: Vec<Stage> =
            stages.iter().copied().filter(|s| *s != Stage::Decode).collect();

        if let Some(job) = queues.try_pop(&non_decode) {
            faults.jobs_done += 1;
            let stage = job.stage();
            let t0 = std::time::Instant::now();
            let units = run_ep(&mut rt, job, &params, queues, metrics, &mut faults, true);
            metrics.on_stage_work(stage, t0.elapsed().as_secs_f64(), units);
            continue;
        }
        if stages.contains(&Stage::Decode) {
            let jobs = queues.pop_decode_batch(params.max_decode_batch as usize);
            if !jobs.is_empty() {
                faults.jobs_done += jobs.len() as u64;
                let t0 = std::time::Instant::now();
                let served =
                    run_decode_batch(&mut rt, jobs, &params, queues, metrics, role, &mut faults);
                metrics.on_stage_work(Stage::Decode, t0.elapsed().as_secs_f64(), served);
                continue;
            }
        }
        // Nothing to do: block briefly; timing out just loops to re-check
        // control/decode.
        if let Some(job) = queues.pop_timeout(&non_decode, Duration::from_millis(5)) {
            faults.jobs_done += 1;
            let stage = job.stage();
            let t0 = std::time::Instant::now();
            let units = run_ep(&mut rt, job, &params, queues, metrics, &mut faults, true);
            metrics.on_stage_work(stage, t0.elapsed().as_secs_f64(), units);
        }
    }
    Ok(())
}

fn warm_for(rt: &mut TinyLmmRuntime, mode: DeploymentMode, role: Stage) -> anyhow::Result<()> {
    for s in pull_stages(mode, role) {
        match s {
            Stage::Encode => rt.warm_encode()?,
            Stage::Prefill => rt.warm_prefill()?,
            Stage::Decode => rt.warm_decode()?,
        }
    }
    Ok(())
}

/// Stage-boundary admission: cancelled jobs (superseded epochs, already
/// failed requests) are skipped silently; expired deadlines fail the
/// request with a structured 504-style error before any further work.
/// Free for default runs: no deadline and no cancellation means two
/// relaxed atomic loads.
fn boundary_reject(job: &Job, queues: &Arc<StageQueues>, metrics: &Arc<MetricsRecorder>) -> bool {
    let ctx = job.ctx();
    if ctx.is_terminated() || ctx.is_cancelled() {
        return true;
    }
    if ctx.past_deadline() {
        fail_and_clean(queues, ctx, FailReason::DeadlineExceeded, metrics);
        return true;
    }
    false
}

/// Pop-side wrapper for EP-stage jobs: stage-boundary admission, an
/// ownership claim, fault injection, then execution. Returns the
/// completed-job units for the monitor's service accounting.
fn run_ep(
    rt: &mut TinyLmmRuntime,
    job: Job,
    params: &InstanceParams,
    queues: &Arc<StageQueues>,
    metrics: &Arc<MetricsRecorder>,
    faults: &mut FaultState,
    kill_armed: bool,
) -> u64 {
    if boundary_reject(&job, queues, metrics) {
        return 0;
    }
    let token = queues.supervision.claim(params.idx, &job);
    if kill_armed {
        faults.maybe_kill();
    }
    if faults.slow_ms > 0 {
        std::thread::sleep(Duration::from_millis(faults.slow_ms));
    }
    handle_ep_job(rt, job, queues, metrics, params, faults, token)
}

/// Encode or prefill one job. `params.pd_layer_groups > 0` streams
/// prefilled KV to the decode side in layer groups instead of one
/// monolithic `Job::Decode`.
///
/// Returns the number of completed stage jobs this call performed (the
/// monitor's service-time unit): an executed encode or prefill counts 1;
/// a streamed chunk that only slots into a reassembly buffer counts 0,
/// so bookkeeping never dilutes the per-job service EWMA.
///
/// `token` is the job's ownership claim: released when the work hands
/// off cleanly, consumed by [`recover_or_fail`] when it doesn't.
fn handle_ep_job(
    rt: &mut TinyLmmRuntime,
    job: Job,
    queues: &Arc<StageQueues>,
    metrics: &Arc<MetricsRecorder>,
    params: &InstanceParams,
    faults: &mut FaultState,
    token: Option<u64>,
) -> u64 {
    let sup = &queues.supervision;
    match job {
        Job::Encode { ctx, shard, patches, tiles, stream } => {
            match rt.encode(&patches, tiles) {
                Ok(mm) => {
                    if stream && faults.take_handoff() {
                        // Injected streamed-handoff error: degrade this
                        // request to the monolithic path (fresh epoch,
                        // single unstreamed shard) instead of failing it.
                        warn!("injected EP handoff error for req {}: falling back", ctx.id);
                        sup.release(token);
                        fallback_monolithic(queues, metrics, &ctx);
                        return 1;
                    }
                    if stream {
                        // Chunked handoff: emit this shard's tokens to the
                        // prefill side the moment they exist — no waiting
                        // for sibling shards. The queue push *is* the EP
                        // transfer; reassembly happens on a prefill worker.
                        queues.account_ep(mm.len() * 4);
                        metrics.on_ep_chunk();
                        queues.push(Stage::Prefill, Job::PrefillChunk { ctx, shard, mm });
                    } else if ctx.shard_done(shard, mm) {
                        // Last shard: EP migration of the merged tokens,
                        // shared between the prefill job and the cache.
                        let merged = Arc::new(ctx.merged_mm());
                        populate_encoder_cache(rt, &ctx, &merged, queues);
                        queues.account_ep(merged.len() * 4);
                        queues.push(Stage::Prefill, Job::Prefill { ctx, mm: merged });
                    }
                    sup.release(token);
                    1
                }
                Err(e) => {
                    warn!("encode failed for req {}: {e:#}", ctx.id);
                    recover_or_fail(queues, metrics, token, &ctx, "encode failed");
                    0
                }
            }
        }
        Job::PrefillChunk { ctx, shard, mm } => {
            // Ordered reassembly at the prefill side: out-of-order shard
            // completion still yields an in-order, byte-identical payload
            // (see `ReassemblyBuffer`). The worker that slots the final
            // chunk runs the request's prefill immediately.
            if let Some(merged) = queues.reassembly.insert(ctx.id, shard, mm) {
                let merged = Arc::new(merged);
                populate_encoder_cache(rt, &ctx, &merged, queues);
                metrics.on_ep_reassembled();
                // The claim now covers the promoted prefill: a crash
                // replays the merged payload, not a consumed chunk.
                let job = Job::Prefill { ctx, mm: merged };
                sup.ledger.update(token, job.clone());
                handle_ep_job(rt, job, queues, metrics, params, faults, token)
            } else {
                sup.release(token);
                0
            }
        }
        Job::Prefill { ctx, mm } => {
            let images = ctx.images.max(1);
            let (bucket_tokens, mm_tokens) = match rt.prefill_bucket_tokens(images) {
                Ok(x) => x,
                Err(e) => {
                    warn!("no prefill bucket for req {}: {e:#}", ctx.id);
                    recover_or_fail(queues, metrics, token, &ctx, "no prefill bucket");
                    return 0;
                }
            };
            // Token layout: [BOS, M placeholders, text..., PAD...].
            let mut tokens: Vec<i32> = vec![tokenizer::BOS as i32];
            tokens.extend(
                std::iter::repeat(tokenizer::IMAGE_PLACEHOLDER as i32).take(mm_tokens as usize),
            );
            let text_budget = (bucket_tokens as usize).saturating_sub(tokens.len());
            tokens.extend(ctx.text_tokens.iter().take(text_budget));
            let len = tokens.len() as i32;
            tokens.resize(bucket_tokens as usize, tokenizer::PAD as i32);

            match rt.prefill(images, &tokens, mm.as_slice(), len) {
                Ok(pf) => {
                    let first = argmax(&pf.logits);
                    metrics.on_first_token(ctx.id);
                    if ctx.max_tokens <= 1 {
                        finish(&ctx, vec![first], metrics);
                        sup.release(token);
                        return 1;
                    }
                    let pd_stream = params.pd_layer_groups > 0 && {
                        if faults.take_handoff() {
                            // Injected streamed PD handoff error: ship the
                            // KV whole for this request instead.
                            warn!("injected PD handoff error for req {}: monolithic KV", ctx.id);
                            metrics.on_degraded_fallback();
                            false
                        } else {
                            true
                        }
                    };
                    if pd_stream {
                        // Streamed PD handoff: the KV leaves in contiguous
                        // layer groups (exact cumulative split — parts
                        // always concatenate back to the monolithic
                        // buffer), each an independent transfer; the
                        // decode worker that completes reassembly admits
                        // the request. Same total bytes as the monolithic
                        // path, counted per chunk.
                        let groups = params.pd_layer_groups as usize;
                        queues.kv_reassembly.expect(ctx.id, groups);
                        metrics.on_pd_streamed();
                        let sizes = crate::util::bytes::cumulative_split(
                            pf.kv.len() as u64,
                            params.pd_layer_groups as u64,
                        );
                        let mut lo = 0usize;
                        for (g, sz) in sizes.into_iter().enumerate() {
                            let hi = lo + sz as usize;
                            let part = pf.kv[lo..hi].to_vec();
                            lo = hi;
                            queues.account_pd(part.len() * 4);
                            metrics.on_pd_chunk();
                            queues.push(
                                Stage::Decode,
                                Job::KvChunk {
                                    ctx: Arc::clone(&ctx),
                                    group: g,
                                    kv: part,
                                    len,
                                    next_token: first,
                                },
                            );
                        }
                    } else {
                        queues.account_pd(pf.kv.len() * 4);
                        queues.push(
                            Stage::Decode,
                            Job::Decode {
                                ctx,
                                kv: pf.kv,
                                len,
                                next_token: first,
                                generated: vec![first],
                            },
                        );
                    }
                    sup.release(token);
                    1
                }
                Err(e) => {
                    warn!("prefill failed for req {}: {e:#}", ctx.id);
                    recover_or_fail(queues, metrics, token, &ctx, "prefill failed");
                    0
                }
            }
        }
        Job::Decode { .. } | Job::KvChunk { .. } => {
            unreachable!("decode-side jobs go through run_decode_batch")
        }
    }
}

/// Graceful degradation off a failed streamed EP handoff: abort the
/// streamed epoch's partial reassembly, start a fresh single-shard epoch
/// of the request, and re-encode the full payload (regenerated from the
/// request seed — byte-identical to the original concatenation) down the
/// monolithic path.
fn fallback_monolithic(
    queues: &Arc<StageQueues>,
    metrics: &Arc<MetricsRecorder>,
    ctx: &Arc<ReqCtx>,
) {
    queues.reassembly.abort(ctx.id);
    let fresh = ctx.respawn(1);
    queues.supervision.track(&fresh);
    metrics.on_degraded_fallback();
    let tiles = fresh.images;
    let patches = synth_patches(fresh.seed, tiles);
    queues.push(
        Stage::Encode,
        Job::Encode { ctx: fresh, shard: 0, patches, tiles, stream: false },
    );
}

/// Miss-path population of the cross-request encoder cache at EP-merge
/// time: instead of the tokens dying with the request, later requests
/// carrying the same media skip encode entirely. The pin is released
/// immediately — the enclosing queue push / prefill run *is* the confirmed
/// intra-process "transfer". Capacity is charged in MM tokens (the payload
/// holds `llm_hidden` floats per token), matching the simulator. A decline
/// (capacity held by pinned entries) changes nothing: the payload is
/// `Arc`-shared, so ownership stays with the prefill job either way — the
/// cache never becomes the payload's only owner while a request needs it.
/// Degradation is bypass by construction: any populate failure leaves the
/// request on the uncached path it was already on.
fn populate_encoder_cache(
    rt: &TinyLmmRuntime,
    ctx: &Arc<ReqCtx>,
    merged: &Arc<Vec<f32>>,
    queues: &Arc<StageQueues>,
) {
    if let Some(h) = ctx.media_hash {
        let mm_tokens = merged.len() as u64 / rt.config().llm_hidden.max(1) as u64;
        let payload = Arc::clone(merged);
        let mut cache = lock_clean(&queues.encoder_cache);
        if cache.insert_pinned(h, mm_tokens, Some(payload)) {
            cache.unpin(h);
        }
    }
}

struct Slot {
    ctx: Arc<ReqCtx>,
    /// Ownership-ledger claim, released when the slot finishes.
    token: Option<u64>,
    generated: Vec<i32>,
    cur: i32,
    done: bool,
}

/// Turn one popped decode-stage job into a batch slot. A monolithic
/// `Job::Decode` admits directly; a streamed `Job::KvChunk` slots into
/// the global reassembly buffer and admits only when it completes the
/// request's KV — whichever decode worker lands the final group runs it.
fn admit_decode_job(
    job: Job,
    token: Option<u64>,
    slots: &mut Vec<Slot>,
    kvs: &mut Vec<Vec<f32>>,
    lens: &mut Vec<i32>,
    queues: &Arc<StageQueues>,
    metrics: &Arc<MetricsRecorder>,
) {
    match job {
        Job::Decode { ctx, kv, len, next_token, generated } => {
            slots.push(Slot { ctx, token, generated, cur: next_token, done: false });
            kvs.push(kv);
            lens.push(len);
        }
        Job::KvChunk { ctx, group, kv, len, next_token } => {
            if let Some(merged) = queues.kv_reassembly.insert(ctx.id, group, kv) {
                metrics.on_pd_reassembled();
                if token.is_some() {
                    // Promote the claim to the fully-reassembled decode:
                    // a crash replays the merged KV, not one chunk.
                    queues.supervision.ledger.update(
                        token,
                        Job::Decode {
                            ctx: Arc::clone(&ctx),
                            kv: merged.clone(),
                            len,
                            next_token,
                            generated: vec![next_token],
                        },
                    );
                }
                slots.push(Slot {
                    ctx,
                    token,
                    generated: vec![next_token],
                    cur: next_token,
                    done: false,
                });
                kvs.push(merged);
                lens.push(len);
            } else {
                // Partial group: the payload now lives in the global
                // reassembly buffer, which survives this worker.
                queues.supervision.release(token);
            }
        }
        _ => unreachable!("non-decode job in the decode queue"),
    }
}

/// Failure path for a decode runtime error: every live slot either
/// retries from its ledger snapshot or fails with a typed error — no
/// receiver is left hanging on a dropped slot.
fn fail_decode_slots(
    slots: &mut [Slot],
    queues: &Arc<StageQueues>,
    metrics: &Arc<MetricsRecorder>,
    what: &str,
) {
    for s in slots.iter_mut() {
        if s.done {
            continue;
        }
        s.done = true;
        recover_or_fail(queues, metrics, s.token.take(), &s.ctx, what);
    }
}

/// Continuous-batching decode loop with periodic queue re-checks (the
/// monolith preemption point, and the join point for waiting requests).
///
/// Returns the number of requests admitted to the batch over the run —
/// the monitor's decode service-time unit. Streamed `Job::KvChunk`s that
/// only slot a partial KV group count 0 (their wall time is negligible
/// bookkeeping; counting them would dilute the per-job service EWMA by
/// the group count).
fn run_decode_batch(
    rt: &mut TinyLmmRuntime,
    jobs: Vec<Job>,
    params: &InstanceParams,
    queues: &Arc<StageQueues>,
    metrics: &Arc<MetricsRecorder>,
    role: Stage,
    faults: &mut FaultState,
) -> u64 {
    let mut slots: Vec<Slot> = Vec::new();
    let mut kvs: Vec<Vec<f32>> = Vec::new();
    let mut lens: Vec<i32> = Vec::new();
    for job in jobs {
        if boundary_reject(&job, queues, metrics) {
            continue;
        }
        let token = queues.supervision.claim(params.idx, &job);
        admit_decode_job(job, token, &mut slots, &mut kvs, &mut lens, queues, metrics);
    }
    // Claims are registered: an injected kill here strands work the
    // supervisor can sweep, never work that silently vanishes.
    faults.maybe_kill();
    if faults.slow_ms > 0 {
        std::thread::sleep(Duration::from_millis(faults.slow_ms));
    }
    let mut served = slots.len() as u64;
    if slots.is_empty() {
        // Only partial KV groups arrived (reassembly still pending on
        // other chunks): nothing to decode yet.
        return 0;
    }

    'outer: loop {
        let kv_refs: Vec<&[f32]> = kvs.iter().map(|k| k.as_slice()).collect();
        let mut state = match rt.decode_start(&kv_refs, &lens) {
            Ok(s) => s,
            Err(e) => {
                warn!("decode_start failed: {e:#}");
                fail_decode_slots(&mut slots, queues, metrics, "decode_start failed");
                return served;
            }
        };
        let bucket = state.batch as usize;

        let mut steps_since_recheck = 0u32;
        loop {
            queues.supervision.beat(params.idx);
            // Build the token vector (idle/finished slots feed PAD).
            let mut tokens = vec![tokenizer::PAD as i32; bucket];
            for (i, s) in slots.iter().enumerate() {
                if !s.done {
                    tokens[i] = s.cur;
                }
            }
            let logits = match rt.decode_step(&mut state, &tokens) {
                Ok(l) => l,
                Err(e) => {
                    warn!("decode_step failed: {e:#}");
                    fail_decode_slots(&mut slots, queues, metrics, "decode_step failed");
                    return served;
                }
            };
            let vocab = rt.config().llm_vocab as usize;
            let max_seq = rt.config().llm_max_seq as i32;
            for (i, s) in slots.iter_mut().enumerate() {
                if s.done {
                    continue;
                }
                let next = argmax(&logits[i * vocab..(i + 1) * vocab]);
                s.generated.push(next);
                s.cur = next;
                let at_cap = state.lens[i] + 1 >= max_seq;
                if s.generated.len() as u32 >= s.ctx.max_tokens
                    || next == tokenizer::EOS as i32
                    || at_cap
                {
                    s.done = true;
                    finish(&s.ctx, s.generated.clone(), metrics);
                    queues.supervision.release(s.token.take());
                }
            }
            if slots.iter().all(|s| s.done) {
                return served;
            }
            steps_since_recheck += 1;
            if steps_since_recheck >= params.decode_recheck_steps {
                steps_since_recheck = 0;
                let stages = pull_stages(params.mode, role);
                let has_ep_work = stages
                    .iter()
                    .any(|&s| s != Stage::Decode && queues.len(s) > 0);
                let can_grow = slots.iter().filter(|s| !s.done).count()
                    < params.max_decode_batch as usize
                    && queues.len(Stage::Decode) > 0;
                if has_ep_work || can_grow {
                    // Re-form: pull live KV back to the host, handle the
                    // EP work / admit waiting sequences, then resume.
                    let extracted = match rt.decode_extract(&state) {
                        Ok(x) => x,
                        Err(e) => {
                            warn!("decode_extract failed: {e:#}");
                            fail_decode_slots(&mut slots, queues, metrics, "decode_extract failed");
                            return served;
                        }
                    };
                    let mut new_slots = Vec::new();
                    let mut new_kvs = Vec::new();
                    let mut new_lens = Vec::new();
                    for (i, s) in slots.drain(..).enumerate() {
                        if !s.done {
                            new_kvs.push(extracted[i].clone());
                            new_lens.push(state.lens[i]);
                            new_slots.push(s);
                        }
                    }
                    drop(state);

                    if has_ep_work {
                        // Preemption (the Figure 1 interference): serve the
                        // EP queue before decoding resumes. Units are
                        // deliberately not recorded — this wall time sits
                        // inside the caller's decode window, so counting
                        // the jobs elsewhere would double-account.
                        let non_decode: Vec<Stage> = stages
                            .iter()
                            .copied()
                            .filter(|s| *s != Stage::Decode)
                            .collect();
                        while let Some(job) = queues.try_pop(&non_decode) {
                            let _ = run_ep(rt, job, params, queues, metrics, faults, false);
                        }
                    }
                    // Admit waiting decode jobs into the freed capacity.
                    let room = params.max_decode_batch as usize - new_slots.len();
                    let before = new_slots.len();
                    for job in queues.pop_decode_batch(room) {
                        if boundary_reject(&job, queues, metrics) {
                            continue;
                        }
                        let token = queues.supervision.claim(params.idx, &job);
                        admit_decode_job(
                            job,
                            token,
                            &mut new_slots,
                            &mut new_kvs,
                            &mut new_lens,
                            queues,
                            metrics,
                        );
                    }
                    served += (new_slots.len() - before) as u64;
                    if new_slots.is_empty() {
                        return served;
                    }
                    slots = new_slots;
                    kvs = new_kvs;
                    lens = new_lens;
                    continue 'outer;
                }
            }
        }
    }
}

/// Deliver a completion. Exactly-once by the terminated CAS: if the
/// request already failed (deadline, drain, worker loss), the late
/// completion is suppressed.
fn finish(ctx: &Arc<ReqCtx>, tokens: Vec<i32>, metrics: &Arc<MetricsRecorder>) {
    if !ctx.try_terminate() {
        return;
    }
    metrics.on_finish(ctx.id, tokens.len() as u32);
    let text = tokenizer::decode(
        &tokens.iter().map(|&t| t.max(0) as u32).collect::<Vec<u32>>(),
    );
    let now = std::time::Instant::now();
    let latency = now.duration_since(ctx.arrival).as_secs_f64();
    let resp = GenResponse::Done(GenOutput {
        id: ctx.id,
        tokens,
        text,
        ttft: f64::NAN, // filled by the engine from the recorder
        latency,
    });
    // Receiver may have gone away (fire-and-forget submits) — ignore.
    let _ = ctx.done_tx.try_send(resp);
}
