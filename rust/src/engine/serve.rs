//! The engine front: spawns instance threads per the deployment config,
//! routes submissions (IRP sharding at entry), runs the role-switch
//! monitor, and exposes synchronous/asynchronous submit APIs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;
use log::info;

use crate::coordinator::monitor::QueueMonitor;
use crate::coordinator::role_switch::{RoleSwitchController, SwitchPolicy};
use crate::core::config::EpdConfig;
use crate::core::stage::Stage;
use crate::metrics::recorder::MetricsRecorder;
use crate::model::tokenizer;
use crate::util::rng::Rng;

use super::instance::{instance_main, Ctrl, InstanceParams};
use super::job::{GenRequest, GenResponse, Job, ReqCtx};
use super::queues::StageQueues;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub artifacts_dir: String,
    pub epd: EpdConfig,
    /// Largest decode batch an instance forms (bounded by decode buckets).
    pub max_decode_batch: u32,
    /// Steps between decode-loop queue re-checks.
    pub decode_recheck_steps: u32,
    /// Role-switch policy (used when `epd.role_switching`).
    pub switch_policy: SwitchPolicy,
}

impl EngineConfig {
    pub fn new(artifacts_dir: &str, epd: EpdConfig) -> EngineConfig {
        EngineConfig {
            artifacts_dir: artifacts_dir.to_string(),
            epd,
            max_decode_batch: 8,
            decode_recheck_steps: 4,
            switch_policy: SwitchPolicy::default(),
        }
    }
}

/// The running engine.
pub struct EpdEngine {
    cfg: EngineConfig,
    queues: Arc<StageQueues>,
    ctrls: Vec<Sender<Ctrl>>,
    handles: Vec<JoinHandle<()>>,
    monitor_handle: Option<JoinHandle<()>>,
    pub metrics: Arc<MetricsRecorder>,
    next_id: AtomicU64,
}

impl EpdEngine {
    /// Start instance threads (each compiles its own executables — expect
    /// a few seconds of warm-up for large topologies).
    pub fn start(cfg: EngineConfig) -> Result<EpdEngine> {
        let roles: Vec<Stage> = cfg.epd.instances.iter().map(|i| i.role).collect();
        let queues = Arc::new(StageQueues::new(roles.clone()));
        let metrics = Arc::new(MetricsRecorder::new());
        let mut ctrls = Vec::new();
        let mut handles = Vec::new();
        for (idx, role) in roles.iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::channel();
            ctrls.push(tx);
            let params = InstanceParams {
                idx,
                role: *role,
                mode: cfg.epd.mode,
                artifacts_dir: cfg.artifacts_dir.clone(),
                max_decode_batch: cfg.max_decode_batch,
                decode_recheck_steps: cfg.decode_recheck_steps,
            };
            let q = Arc::clone(&queues);
            let m = Arc::clone(&metrics);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("epd-inst-{idx}"))
                    .spawn(move || instance_main(params, q, rx, m))?,
            );
        }

        let monitor_handle = if cfg.epd.role_switching {
            let q = Arc::clone(&queues);
            let ctrls2 = ctrls.clone();
            let policy = cfg.switch_policy;
            Some(std::thread::spawn(move || monitor_main(q, ctrls2, policy)))
        } else {
            None
        };

        info!(
            "engine started: mode={} topology={}",
            cfg.epd.mode.name(),
            cfg.epd.topology()
        );
        Ok(EpdEngine {
            cfg,
            queues,
            ctrls,
            handles,
            monitor_handle,
            metrics,
            next_id: AtomicU64::new(1),
        })
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = sync_channel(1);
        let id = req.id;
        self.metrics.on_arrival(id);

        let text_tokens: Vec<i32> = tokenizer::encode(&req.prompt)[1..] // drop BOS (layout adds it)
            .iter()
            .map(|&t| t as i32)
            .collect();

        let tiles = req.images; // tiny-lmm: one tile per image
        // IRP fan-out: shard across the instances currently encoding.
        let fanout = if self.cfg.epd.irp {
            self.queues.role_count(Stage::Encode).max(1).min(tiles.max(1))
        } else {
            1
        };
        let plan = crate::coordinator::irp::plan_shards(tiles, fanout, self.cfg.epd.irp);
        let shards_total = plan.num_shards().max(1);

        let ctx = Arc::new(ReqCtx::new(
            id,
            req.images,
            text_tokens,
            req.max_tokens,
            shards_total,
            tx,
        ));

        if tiles == 0 {
            // Text-only: straight to prefill with zero MM tokens.
            self.queues.push(Stage::Prefill, Job::Prefill { ctx, mm: vec![] });
            return rx;
        }

        // Generate synthetic patch data per tile (the "image"): content is
        // a pure function of the caller-provided seed, so identical
        // requests reproduce identical tokens regardless of request id.
        let mut rng = Rng::new(req.seed);
        let per_tile = 64 * 192; // num_patches × patch_dim
        let mut tile_cursor = 0u32;
        for (shard, &shard_tiles) in plan.tiles_per_shard.iter().enumerate() {
            let mut patches = Vec::with_capacity((shard_tiles as usize) * per_tile);
            for _ in 0..shard_tiles {
                for _ in 0..per_tile {
                    patches.push(rng.f64() as f32);
                }
            }
            tile_cursor += shard_tiles;
            self.queues.push(
                Stage::Encode,
                Job::Encode {
                    ctx: Arc::clone(&ctx),
                    shard,
                    patches,
                    tiles: shard_tiles,
                },
            );
        }
        debug_assert_eq!(tile_cursor, tiles);
        rx
    }

    /// Convenience: submit and wait.
    pub fn generate(&self, images: u32, prompt: &str, max_tokens: u32) -> Result<GenResponse> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let rx = self.submit(GenRequest {
            id,
            images,
            prompt: prompt.to_string(),
            max_tokens,
            seed: 0x5EED,
        });
        Ok(rx.recv()?)
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    pub fn queues(&self) -> &Arc<StageQueues> {
        &self.queues
    }

    /// Graceful shutdown: waits for instance threads.
    pub fn shutdown(mut self) {
        self.queues.begin_shutdown();
        for c in &self.ctrls {
            let _ = c.send(Ctrl::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.monitor_handle.take() {
            let _ = h.join();
        }
    }
}

/// Role-switch monitor thread (§3.2.4): samples queue depths, feeds the
/// EWMA monitor, and instructs the least-loaded donor instance to switch
/// when the controller fires.
fn monitor_main(queues: Arc<StageQueues>, ctrls: Vec<Sender<Ctrl>>, policy: SwitchPolicy) {
    let mut monitor = QueueMonitor::new(0.4);
    let mut controller = RoleSwitchController::new(policy);
    let t0 = std::time::Instant::now();
    while !queues.is_shutdown() {
        std::thread::sleep(Duration::from_millis(100));
        let now = t0.elapsed().as_secs_f64();
        let counts = [
            queues.role_count(Stage::Encode),
            queues.role_count(Stage::Prefill),
            queues.role_count(Stage::Decode),
        ];
        for s in Stage::ALL {
            let qlen = queues.len(s);
            // Backlog proxy: queue length (the engine has no cost model —
            // deliberately; it measures rather than predicts).
            monitor.observe(s, qlen, qlen as f64, 0.0, counts[stage_idx(s)]);
        }
        if let Some(dec) = controller.evaluate(now, &monitor, counts) {
            // Donor: any instance currently in `dec.from`.
            let roles = queues.roles.lock().unwrap().clone();
            if let Some(idx) = roles.iter().position(|&r| r == dec.from) {
                queues.set_role(idx, dec.to);
                let _ = ctrls[idx].send(Ctrl::Switch {
                    to: dec.to,
                    pause: Duration::from_secs_f64(dec.migration_time),
                });
                info!("monitor: switching instance {idx} {} -> {}", dec.from, dec.to);
            }
        }
    }
}

fn stage_idx(s: Stage) -> usize {
    match s {
        Stage::Encode => 0,
        Stage::Prefill => 1,
        Stage::Decode => 2,
    }
}
