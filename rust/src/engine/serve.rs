//! The engine front: spawns instance threads per the deployment config,
//! routes submissions (IRP sharding at entry), runs the role-switch
//! monitor, and exposes synchronous/asynchronous submit APIs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;
use log::info;

use crate::api::{ApiError, SubmitRequest};
use crate::coordinator::planner::{PlannerConfig, ReallocationPlanner};
use crate::coordinator::profiler::WorkloadProfiler;
use crate::coordinator::role_switch::SwitchPolicy;
use crate::core::config::EpdConfig;
use crate::core::request::Priority;
use crate::core::stage::Stage;
use crate::metrics::recorder::MetricsRecorder;
use crate::model::tokenizer;
use crate::router::{decide, AdmissionDecision, AdmissionOutlook, RouterConfig};
use crate::util::rng::Rng;

use super::instance::{instance_main, Ctrl, InstanceParams};
use super::job::{FailReason, GenFailure, GenOutput, GenRequest, GenResponse, Job, ReqCtx};
use super::queues::StageQueues;
use super::supervise::{
    fail_and_clean, lock_clean, stage_has_healthy, supervise_tick, EngineFaultPlan, Supervision,
};

/// Engine configuration.
///
/// Start from [`EngineConfig::new`] and override fields as needed:
///
/// ```no_run
/// use epdserve::core::config::EpdConfig;
/// use epdserve::core::topology::Topology;
/// use epdserve::engine::serve::{EngineConfig, EpdEngine};
///
/// // 2 encode, 1 prefill, 1 decode instance over prebuilt artifacts.
/// let mut epd = EpdConfig::epd(Topology::new(2, 1, 1), 1, 1, 128);
/// epd.encoder_cache_tokens = 1 << 18; // 256Ki MM tokens of media reuse
/// let mut cfg = EngineConfig::new("artifacts", epd);
/// cfg.max_decode_batch = 16;          // larger continuous batches
/// let engine = EpdEngine::start(cfg).unwrap();
/// let resp = engine.generate(2, "what do you see?", 12).unwrap();
/// assert_eq!(resp.tokens.len(), 12);
/// engine.shutdown();
/// ```
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Directory holding the AOT artifacts produced by
    /// `python -m compile.aot` (`manifest.json`, `weights.bin`, HLO text).
    pub artifacts_dir: String,
    /// Deployment: mode, per-instance roles/batches, IRP and role-switch
    /// toggles, and the cross-request encoder-cache capacity
    /// (`EpdConfig::encoder_cache_tokens`; 0 disables media reuse).
    pub epd: EpdConfig,
    /// Largest decode batch an instance forms (bounded by decode buckets).
    pub max_decode_batch: u32,
    /// Steps between decode-loop queue re-checks — the preemption/join
    /// granularity of continuous batching.
    pub decode_recheck_steps: u32,
    /// Role-switch policy (used when `epd.role_switching`).
    pub switch_policy: SwitchPolicy,
    /// Deterministic fault injection for chaos tests. Empty (the
    /// default) resolves from `epd.engine_fault_seed` — which is itself
    /// 0 (dormant) by default — so production runs inject nothing.
    pub fault_plan: EngineFaultPlan,
}

impl EngineConfig {
    pub fn new(artifacts_dir: &str, epd: EpdConfig) -> EngineConfig {
        EngineConfig {
            artifacts_dir: artifacts_dir.to_string(),
            epd,
            max_decode_batch: 8,
            decode_recheck_steps: 4,
            switch_policy: SwitchPolicy::default(),
            fault_plan: EngineFaultPlan::none(),
        }
    }
}

/// The running engine.
pub struct EpdEngine {
    cfg: EngineConfig,
    queues: Arc<StageQueues>,
    ctrls: Vec<Sender<Ctrl>>,
    handles: Vec<JoinHandle<()>>,
    monitor_handle: Option<JoinHandle<()>>,
    pub metrics: Arc<MetricsRecorder>,
    next_id: AtomicU64,
    /// Front-door admission config; `None` when `router = "off"` — the
    /// typed submit path then behaves exactly like the legacy one.
    router: Option<RouterConfig>,
}

impl EpdEngine {
    /// Start instance threads (each compiles its own executables — expect
    /// a few seconds of warm-up for large topologies).
    pub fn start(cfg: EngineConfig) -> Result<EpdEngine> {
        let roles: Vec<Stage> = cfg.epd.instances.iter().map(|i| i.role).collect();
        let supervision = Supervision::from_epd(&cfg.epd, roles.len());
        let queues = Arc::new(StageQueues::with_supervision(
            roles.clone(),
            cfg.epd.encoder_cache_tokens,
            supervision,
        ));
        let metrics = Arc::new(MetricsRecorder::new());
        // Explicit plan wins; otherwise resolve from config (dormant at
        // the default `engine_fault_seed = 0`).
        let plan = if cfg.fault_plan.is_empty() {
            EngineFaultPlan::from_epd(&cfg.epd)
        } else {
            cfg.fault_plan.clone()
        }
        .clamp_instances(roles.len());
        let mut ctrls = Vec::new();
        let mut handles = Vec::new();
        for (idx, role) in roles.iter().enumerate() {
            let (tx, rx) = std::sync::mpsc::channel();
            ctrls.push(tx);
            let params = InstanceParams {
                idx,
                role: *role,
                mode: cfg.epd.mode,
                artifacts_dir: cfg.artifacts_dir.clone(),
                max_decode_batch: cfg.max_decode_batch,
                decode_recheck_steps: cfg.decode_recheck_steps,
                pd_layer_groups: cfg.epd.pd_layer_groups,
                kill_after_jobs: plan.kill_after(idx),
                fault_slow_ms: plan.slow_ms(idx),
                fault_handoff_after: plan.handoff_after(idx),
            };
            let q = Arc::clone(&queues);
            let m = Arc::clone(&metrics);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("epd-inst-{idx}"))
                    .spawn(move || instance_main(params, q, rx, m))?,
            );
        }

        // The monitor doubles as the supervisor: it runs whenever role
        // switching *or* supervision is on.
        let monitor_handle = if cfg.epd.role_switching || cfg.epd.supervise {
            let q = Arc::clone(&queues);
            let ctrls2 = ctrls.clone();
            let policy = cfg.switch_policy;
            let epd = cfg.epd.clone();
            let m = Arc::clone(&metrics);
            Some(std::thread::spawn(move || monitor_main(q, ctrls2, policy, epd, m)))
        } else {
            None
        };

        info!(
            "engine started: mode={} topology={}",
            cfg.epd.mode.name(),
            cfg.epd.topology()
        );
        let router = RouterConfig::from_epd(&cfg.epd);
        Ok(EpdEngine {
            cfg,
            queues,
            ctrls,
            handles,
            monitor_handle,
            metrics,
            next_id: AtomicU64::new(1),
            router,
        })
    }

    /// The typed front-door submit: runs SLO-aware admission (when
    /// `router = "on"`) before lowering to [`EpdEngine::submit`].
    ///
    /// Returns the assigned request id plus the response receiver, or a
    /// structured [`ApiError`] — a shed decision surfaces as 429 with a
    /// `retry_after_ms` hint; a degrade decision caps `max_tokens` and
    /// drops the request to the batch class but still serves it.
    pub fn submit_request(
        &self,
        mut req: SubmitRequest,
    ) -> Result<(u64, Receiver<GenResponse>), ApiError> {
        if self.queues.supervision.is_draining() {
            return Err(ApiError::draining(self.retry_hint_ms()));
        }
        // Circuit breakers at the typed front door (`health_breaker`):
        // a request whose path needs a stage with no healthy (alive and
        // breaker-admitting) instance is shed with a retry hint instead
        // of queueing onto a fabric that cannot serve it. The engine's
        // pull-based dispatch needs no per-instance steering beyond this
        // — a breaker-refused instance is either dead (it pulls nothing)
        // or probing its way back through the shared queues.
        if self.queues.supervision.health_active() {
            let mode = self.cfg.epd.mode;
            let mut stages = vec![Stage::Prefill, Stage::Decode];
            if req.media.images > 0 {
                stages.push(Stage::Encode);
            }
            if stages.iter().any(|&s| !stage_has_healthy(&self.queues, mode, s)) {
                return Err(ApiError::shed(self.retry_hint_ms()));
            }
        }
        if let Some(rc) = &self.router {
            let outlook = self.router_outlook(req.media.images);
            let budget = if req.deadline_ms == 0 {
                f64::INFINITY
            } else {
                req.deadline_ms as f64 / 1000.0
            };
            match decide(rc, &outlook, req.priority, budget) {
                AdmissionDecision::Admit => {}
                AdmissionDecision::Degrade { max_tokens } => {
                    req.max_tokens = req.max_tokens.min(max_tokens);
                    req.priority = Priority::Batch;
                    self.metrics.on_router_degraded();
                }
                AdmissionDecision::Shed { retry_after_ms } => {
                    self.metrics.on_router_shed();
                    return Err(ApiError::shed(retry_after_ms));
                }
            }
        }
        let id = self.fresh_id();
        let rx = self.submit(req.into_gen(id));
        Ok((id, rx))
    }

    /// Admission projection from live queue depths priced at the
    /// worker-measured mean service times (the engine-side analogue of
    /// the simulator's profiler-EWMA outlook). Before the first job of a
    /// stage completes its mean is 0 — warm-up admits by construction.
    fn router_outlook(&self, images: u32) -> AdmissionOutlook {
        let svc = |s: Stage| -> f64 {
            let jobs = self.metrics.stage_jobs(s);
            if jobs == 0 {
                0.0
            } else {
                self.metrics.stage_busy_seconds(s) / jobs as f64
            }
        };
        let wait = |s: Stage| -> f64 {
            self.queues.len(s) as f64 * svc(s) / self.queues.role_count(s).max(1) as f64
        };
        let mut outlook = AdmissionOutlook {
            prefill_wait: wait(Stage::Prefill),
            prefill_cost: svc(Stage::Prefill),
            decode_step: svc(Stage::Decode),
            ..Default::default()
        };
        if images > 0 {
            // Multimodal path: wait behind the encode queue, plus one
            // shard's own encode service (IRP shards run in parallel).
            outlook.entry_wait = wait(Stage::Encode);
            outlook.encode_cost = svc(Stage::Encode);
        }
        outlook
    }

    /// Submit a request; returns a receiver for the response.
    ///
    /// Admission computes the media's content hash and consults the
    /// cross-request encoder cache: a hit routes the request straight to
    /// prefill with the cached MM tokens — no patch generation, no IRP
    /// fan-out, no encode occupancy. A miss proceeds through encode and
    /// populates the cache when the last shard merges.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = sync_channel(1);
        let id = req.id;
        if self.queues.supervision.is_draining() {
            // Drain: intake is closed. The request is rejected before it
            // is counted as submitted, so the termination ledger
            // (`finished + failed == submitted`) is unaffected.
            let _ = tx.try_send(GenResponse::Failed(GenFailure {
                id,
                reason: FailReason::Draining,
                retries: 0,
                latency: 0.0,
            }));
            return rx;
        }
        self.metrics.on_arrival(id);

        let text_tokens: Vec<i32> = tokenizer::encode(&req.prompt)[1..] // drop BOS (layout adds it)
            .iter()
            .map(|&t| t as i32)
            .collect();
        // Request-shape accumulators: the monitor thread's profiler turns
        // the per-window deltas into images/prompt/output EWMAs.
        self.metrics
            .on_request_shape(req.images, text_tokens.len() as u32, req.max_tokens);

        let tiles = req.images; // tiny-lmm: one tile per image
        // Content address of the media payload. Tiny-lmm's synthetic
        // pixels are a pure function of (seed, images), so hashing those
        // two words is exactly hashing the image bytes — a real frontend
        // would run `cache::content_hash` over the decoded media instead.
        let media_hash = if tiles > 0 {
            Some(crate::cache::content_hash_words(&[req.seed, req.images as u64]))
        } else {
            None
        };

        // IRP fan-out: shard across the instances currently encoding.
        let fanout = if self.cfg.epd.irp {
            self.queues.role_count(Stage::Encode).max(1).min(tiles.max(1))
        } else {
            1
        };
        // Chunked EP streaming: shards emit their tokens to the prefill
        // side as they complete instead of merging on the last shard.
        // Shard boundaries align to chunk boundaries (tiny-lmm emits
        // `ENCODER_CACHE_BLOCK_TOKENS` MM tokens per tile).
        let chunk_tokens = self.cfg.epd.ep_chunk_tokens;
        let stream = chunk_tokens > 0 && tiles > 0;
        let plan = if stream {
            let align = (chunk_tokens
                / super::queues::ENCODER_CACHE_BLOCK_TOKENS as u64)
                .clamp(1, u32::MAX as u64) as u32;
            crate::coordinator::irp::plan_shards_aligned(tiles, fanout, self.cfg.epd.irp, align)
        } else {
            crate::coordinator::irp::plan_shards(tiles, fanout, self.cfg.epd.irp)
        };
        let shards_total = plan.num_shards().max(1);

        let ctx = Arc::new(
            ReqCtx::new(
                id,
                req.images,
                text_tokens,
                req.max_tokens,
                media_hash,
                shards_total,
                tx,
            )
            .with_seed(req.seed)
            .with_deadline_ms(req.deadline_ms),
        );
        self.queues.supervision.track(&ctx);

        if tiles == 0 {
            // Text-only: straight to prefill with zero MM tokens.
            self.queues.push(Stage::Prefill, Job::Prefill { ctx, mm: Arc::new(vec![]) });
            return rx;
        }

        if let Some(h) = media_hash {
            let cached = {
                let mut cache = lock_clean(&self.queues.encoder_cache);
                if cache.lookup_pin(h).is_some() {
                    let payload = cache.payload(h);
                    // The Arc clone keeps the tokens alive independently
                    // of the entry, so the pin can be released here.
                    cache.unpin(h);
                    payload
                } else {
                    None
                }
            };
            self.metrics.on_encoder_cache(cached.is_some());
            if let Some(mm) = cached {
                // Zero-copy hit: the job shares the cached buffer.
                self.queues.push(Stage::Prefill, Job::Prefill { ctx, mm });
                return rx;
            }
        }

        // Miss under streaming: register the reassembly slots before any
        // encode job can complete, and count the request as streamed.
        if stream {
            self.queues.reassembly.expect(id, shards_total as usize);
            self.metrics.on_ep_streamed();
        }

        // Generate synthetic patch data per tile (the "image"): content is
        // a pure function of the caller-provided seed, so identical
        // requests reproduce identical tokens regardless of request id —
        // and the monolithic degrade path can regenerate the exact bytes
        // from (seed, tiles) alone.
        let all = synth_patches(req.seed, tiles);
        let mut tile_cursor = 0u32;
        for (shard, &shard_tiles) in plan.tiles_per_shard.iter().enumerate() {
            let lo = tile_cursor as usize * PATCHES_PER_TILE;
            let hi = lo + shard_tiles as usize * PATCHES_PER_TILE;
            let patches = all[lo..hi].to_vec();
            tile_cursor += shard_tiles;
            self.queues.push(
                Stage::Encode,
                Job::Encode {
                    ctx: Arc::clone(&ctx),
                    shard,
                    patches,
                    tiles: shard_tiles,
                    stream,
                },
            );
        }
        debug_assert_eq!(tile_cursor, tiles);
        rx
    }

    /// Convenience: submit and wait (through the typed front door).
    pub fn generate(&self, images: u32, prompt: &str, max_tokens: u32) -> Result<GenOutput> {
        let req = SubmitRequest::new(prompt)
            .images(images)
            .max_tokens(max_tokens)
            .seed(0x5EED);
        let (_, rx) = self.submit_request(req)?;
        self.wait(&rx, 0).map_err(anyhow::Error::from)
    }

    /// The `retry_after_ms` hint attached to retryable (503) errors.
    fn retry_hint_ms(&self) -> u64 {
        self.cfg.epd.retry_base_ms.max(1)
    }

    /// Wait for a submitted request's response, mapping every failure
    /// mode to a structured [`ApiError`]:
    ///
    /// - a typed [`GenResponse::Failed`] maps by its [`FailReason`]
    ///   (worker loss → 503, deadline → 504, drain → 503);
    /// - a dropped sender (request lost with supervision off) → 503
    ///   `worker_lost` instead of a bare channel error;
    /// - the client-side watchdog: with `deadline_ms > 0` the wait is
    ///   bounded by `deadline + supervise_grace_ms`, so no caller blocks
    ///   past the deadline even if every worker wedges → 504.
    pub fn wait(
        &self,
        rx: &Receiver<GenResponse>,
        deadline_ms: u64,
    ) -> Result<GenOutput, ApiError> {
        let hint = self.retry_hint_ms();
        let resp = if deadline_ms > 0 {
            let grace = self.cfg.epd.supervise_grace_ms;
            match rx.recv_timeout(Duration::from_millis(deadline_ms.saturating_add(grace))) {
                Ok(r) => r,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    return Err(ApiError::deadline_exceeded(deadline_ms, hint));
                }
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    return Err(ApiError::worker_lost(hint));
                }
            }
        } else {
            match rx.recv() {
                Ok(r) => r,
                Err(_) => return Err(ApiError::worker_lost(hint)),
            }
        };
        match resp {
            GenResponse::Done(out) => Ok(out),
            GenResponse::Failed(f) => Err(f.to_api_error(deadline_ms, hint)),
        }
    }

    pub fn fresh_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    pub fn queues(&self) -> &Arc<StageQueues> {
        &self.queues
    }

    /// Graceful shutdown: with `drain_timeout_ms > 0`, first drain —
    /// close intake, keep supervising until every in-flight request
    /// terminates (finishes or fails with a typed error), and past the
    /// bound fail the stragglers with a structured `draining` error so
    /// no receiver is silently dropped. Then stop instance threads.
    pub fn shutdown(mut self) {
        let drain_ms = self.cfg.epd.drain_timeout_ms;
        if drain_ms > 0 {
            self.queues.supervision.begin_drain();
            let t0 = std::time::Instant::now();
            loop {
                supervise_tick(&self.queues, &self.metrics, self.cfg.epd.mode);
                let done = self.metrics.finished() as u64 + self.metrics.failed();
                if done >= self.metrics.submitted() as u64 {
                    break;
                }
                if t0.elapsed() >= Duration::from_millis(drain_ms) {
                    for ctx in self.queues.supervision.live_requests() {
                        fail_and_clean(&self.queues, &ctx, FailReason::Draining, &self.metrics);
                    }
                    break;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        self.queues.begin_shutdown();
        for c in &self.ctrls {
            let _ = c.send(Ctrl::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.monitor_handle.take() {
            let _ = h.join();
        }
    }
}

/// Reallocation monitor thread (§3.2.3 + §3.2.4): samples the worker-side
/// counters in [`MetricsRecorder`] into the shared [`WorkloadProfiler`] —
/// measured per-stage busy fractions and per-job service-time EWMAs, not
/// the old `qlen`-as-backlog proxy with hard-coded zero utilization — and
/// drives the same [`ReallocationPlanner`] executor the simulator uses,
/// applying released steps through the instances' `Ctrl::Switch` channel.
///
/// Sample period and EWMA weight come from `EpdConfig::{sample_interval,
/// monitor_alpha}` (defaults: the previously hard-coded 100 ms / 0.4).
fn monitor_main(
    queues: Arc<StageQueues>,
    ctrls: Vec<Sender<Ctrl>>,
    policy: SwitchPolicy,
    epd: EpdConfig,
    metrics: Arc<MetricsRecorder>,
) {
    let sample = Duration::from_secs_f64(epd.sample_interval.max(0.001));
    let mut profiler = WorkloadProfiler::new(epd.monitor_alpha.clamp(0.01, 1.0));
    let mut planner = ReallocationPlanner::new(PlannerConfig::from_epd(&epd, policy));
    // Fault-aware replanning (`health_replan`): a crash swept this tick
    // forces the planner to re-plan immediately instead of waiting out
    // its cadence.
    let health_replan = crate::router::health::HealthConfig::from_epd(&epd)
        .is_some_and(|hc| hc.replan);
    let t0 = std::time::Instant::now();
    let mut prev_busy = [0.0f64; 3];
    let mut prev_jobs = [0u64; 3];
    let mut prev_submitted = 0u64;
    let mut prev_shape = (0u64, 0u64, 0u64);
    while !queues.is_shutdown() {
        std::thread::sleep(sample);
        // Supervision pass: heartbeat staleness, crash sweeps, due
        // retries, uncovered-stage evacuation, deadline watchdog. A
        // no-op (five cheap checks) when supervision is off.
        let crashes_swept = supervise_tick(&queues, &metrics, epd.mode);
        if !epd.role_switching {
            continue;
        }
        if health_replan && crashes_swept > 0 {
            planner.force_plan();
        }
        let now = t0.elapsed().as_secs_f64();
        let counts = [
            queues.role_count(Stage::Encode),
            queues.role_count(Stage::Prefill),
            queues.role_count(Stage::Decode),
        ];
        // Arrival-rate and request-shape EWMAs from the recorder's
        // submission counters.
        let submitted = metrics.submitted() as u64;
        if submitted > prev_submitted {
            let n = submitted - prev_submitted;
            let shape = metrics.request_shape_totals();
            let d = (
                shape.0 - prev_shape.0,
                shape.1 - prev_shape.1,
                shape.2 - prev_shape.2,
            );
            profiler.note_arrivals(n, now);
            profiler.observe_request(
                d.0 as f64 / n as f64,
                d.1 as f64 / n as f64,
                d.2 as f64 / n as f64,
                0.0, // MM tokens are not known at submit in the engine
            );
            prev_submitted = submitted;
            prev_shape = shape;
        }
        let window = sample.as_secs_f64();
        let mut queued = [false; 3];
        for s in Stage::ALL {
            let i = s.index();
            let qlen = queues.len(s);
            queued[i] = qlen > 0;
            let busy = metrics.stage_busy_seconds(s);
            let jobs = metrics.stage_jobs(s);
            let d_busy = (busy - prev_busy[i]).max(0.0);
            let d_jobs = jobs.saturating_sub(prev_jobs[i]);
            prev_busy[i] = busy;
            prev_jobs[i] = jobs;
            if d_jobs > 0 {
                profiler.observe_service(s, d_busy / d_jobs as f64);
            }
            // Busy fraction of this stage's instances over the window.
            let util = if counts[i] == 0 {
                0.0
            } else {
                (d_busy / (window * counts[i] as f64)).clamp(0.0, 1.0)
            };
            // Backlog: queued jobs priced at the measured per-job service
            // time. Until the first job completes, 1 s/job reproduces the
            // old qlen-proxy magnitude.
            let backlog = qlen as f64 * profiler.service_estimate(s).unwrap_or(1.0);
            profiler.observe_stage(s, qlen, backlog, util, counts[i]);
        }
        if let Some(step) = planner.tick(now, &profiler, counts, queued) {
            // Donor: any instance currently in `step.from`.
            let roles = queues.roles_snapshot();
            if let Some(idx) = roles.iter().position(|&r| r == step.from) {
                queues.set_role(idx, step.to);
                let _ = ctrls[idx].send(Ctrl::Switch {
                    to: step.to,
                    pause: Duration::from_secs_f64(step.migration_time),
                });
                metrics.on_role_switch();
                info!("monitor: switching instance {idx} {} -> {}", step.from, step.to);
            } else {
                // No instance currently holds the donor role: hand a
                // predictive step back so the plan retries instead of
                // silently skipping the move.
                planner.requeue(step);
            }
        }
        metrics.record_reallocation(planner.stats());
    }
}

/// Patch floats per tile: num_patches × patch_dim of the tiny-lmm encoder.
pub(crate) const PATCHES_PER_TILE: usize = 64 * 192;

/// Synthetic patch payload for `tiles` tiles: a pure function of the
/// request seed. Submit slices this buffer into IRP shards; the
/// monolithic degrade path regenerates it whole — concatenating the
/// shard slices always reproduces exactly these bytes.
pub(crate) fn synth_patches(seed: u64, tiles: u32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let n = tiles as usize * PATCHES_PER_TILE;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(rng.f64() as f32);
    }
    out
}
