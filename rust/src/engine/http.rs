//! Minimal HTTP/1.1 frontend over `std::net` (no hyper/axum offline):
//! thread-per-connection, enough of the protocol for the API surface:
//!
//! - `POST /v1/completions` — generate (blocking until completion).
//!   The body is a versioned [`SubmitRequest`]; `X-Tenant` and
//!   `X-Priority` headers override the body's `tenant`/`priority`
//!   fields (so a gateway can stamp identity without rewriting JSON).
//!   Malformed fields are field-level 400s with machine-readable codes;
//!   an admission shed is a 429 carrying `retry_after_ms`.
//! - `GET  /metrics`        — live TTFT/TPOT/latency report (JSON)
//! - `GET  /healthz`        — liveness

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};
use log::{info, warn};

use crate::api::{completion_response, error_response, ApiError, SubmitRequest};
use crate::core::request::Priority;
use crate::engine::serve::EpdEngine;
use crate::util::json::Json;

/// Request-scoped header overrides captured by the connection reader.
#[derive(Debug, Default)]
struct Headers {
    content_length: usize,
    tenant: Option<u32>,
    priority: Option<Priority>,
}

/// A running HTTP server.
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve on a background thread. `addr` like "127.0.0.1:8080"
    /// (port 0 picks a free port).
    pub fn serve(engine: Arc<EpdEngine>, addr: &str) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).context("binding http listener")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            info!("http: serving on {local}");
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let eng = Arc::clone(&engine);
                        std::thread::spawn(move || {
                            if let Err(e) = handle_conn(stream, &eng) {
                                warn!("http: connection error: {e:#}");
                            }
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(e) => {
                        warn!("http: accept error: {e}");
                        break;
                    }
                }
            }
        });
        Ok(HttpServer { addr: local, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, engine: &Arc<EpdEngine>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();

    // Headers.
    let mut headers = Headers::default();
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        let lower = line.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            headers.content_length = v.trim().parse().unwrap_or(0);
        } else if let Some(v) = lower.strip_prefix("x-tenant:") {
            headers.tenant = v.trim().parse().ok();
        } else if let Some(v) = lower.strip_prefix("x-priority:") {
            headers.priority = Priority::parse(v.trim());
        }
    }
    let mut body = vec![0u8; headers.content_length.min(1 << 20)];
    if headers.content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    let body = String::from_utf8_lossy(&body).into_owned();

    let (status, payload) = route(&method, &path, &body, &headers, engine);
    respond(stream, status, &payload.to_string())
}

fn route(
    method: &str,
    path: &str,
    body: &str,
    headers: &Headers,
    engine: &Arc<EpdEngine>,
) -> (u16, Json) {
    match (method, path) {
        ("GET", "/healthz") => (200, Json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/metrics") => (200, engine.metrics.report()),
        ("POST", "/v1/completions") => {
            let parsed = match Json::parse(body) {
                Ok(j) => j,
                Err(e) => return (400, error_response("bad_json", &format!("bad json: {e}"))),
            };
            let mut req = match SubmitRequest::from_json(&parsed) {
                Ok(r) => r,
                Err(e) => return (e.status, e.to_json()),
            };
            if let Some(t) = headers.tenant {
                req.tenant = t;
            }
            if let Some(p) = headers.priority {
                req.priority = p;
            }
            // Capture before submit consumes the request: the wait
            // watchdog bounds the blocking recv by deadline + grace.
            let deadline_ms = req.deadline_ms;
            let (id, rx) = match engine.submit_request(req) {
                Ok(pair) => pair,
                Err(e) => return (e.status, e.to_json()),
            };
            // Typed failure mapping: worker loss → 503 worker_lost,
            // deadline → 504 deadline_exceeded, drain → 503 draining —
            // all carrying retry_after_ms. Never a hung connection.
            match engine.wait(&rx, deadline_ms) {
                Ok(out) => (
                    200,
                    completion_response(id, &out.text, out.tokens.len(), out.ttft, out.latency),
                ),
                Err(e) => (e.status, e.to_json()),
            }
        }
        _ => (404, ApiError::not_found().to_json()),
    }
}

fn respond(mut stream: TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}
