//! Minimal HTTP/1.1 frontend over `std::net` (no hyper/axum offline):
//! thread-per-connection, enough of the protocol for the API surface:
//!
//! - `POST /v1/completions` — generate (blocking until completion)
//! - `GET  /metrics`        — live TTFT/TPOT/latency report (JSON)
//! - `GET  /healthz`        — liveness

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};
use log::{info, warn};

use crate::api::{completion_response, error_response, CompletionRequest};
use crate::engine::job::GenRequest;
use crate::engine::serve::EpdEngine;
use crate::util::json::Json;

/// A running HTTP server.
pub struct HttpServer {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind and serve on a background thread. `addr` like "127.0.0.1:8080"
    /// (port 0 picks a free port).
    pub fn serve(engine: Arc<EpdEngine>, addr: &str) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr).context("binding http listener")?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            info!("http: serving on {local}");
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let eng = Arc::clone(&engine);
                        std::thread::spawn(move || {
                            if let Err(e) = handle_conn(stream, &eng) {
                                warn!("http: connection error: {e:#}");
                            }
                        });
                    }
                    Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(e) => {
                        warn!("http: accept error: {e}");
                        break;
                    }
                }
            }
        });
        Ok(HttpServer { addr: local, stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(stream: TcpStream, engine: &Arc<EpdEngine>) -> Result<()> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut request_line = String::new();
    reader.read_line(&mut request_line)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();

    // Headers.
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        reader.read_line(&mut line)?;
        let line = line.trim();
        if line.is_empty() {
            break;
        }
        if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; content_length.min(1 << 20)];
    if content_length > 0 {
        reader.read_exact(&mut body)?;
    }
    let body = String::from_utf8_lossy(&body).into_owned();

    let (status, payload) = route(&method, &path, &body, engine);
    respond(stream, status, &payload.to_string())
}

fn route(method: &str, path: &str, body: &str, engine: &Arc<EpdEngine>) -> (u16, Json) {
    match (method, path) {
        ("GET", "/healthz") => (200, Json::obj(vec![("ok", Json::Bool(true))])),
        ("GET", "/metrics") => (200, engine.metrics.report()),
        ("POST", "/v1/completions") => {
            let parsed = match Json::parse(body) {
                Ok(j) => j,
                Err(e) => return (400, error_response(&format!("bad json: {e}"))),
            };
            let req = match CompletionRequest::from_json(&parsed) {
                Ok(r) => r,
                Err(e) => return (400, error_response(&format!("bad request: {e}"))),
            };
            let id = engine.fresh_id();
            let rx = engine.submit(GenRequest {
                id,
                images: req.images,
                prompt: req.prompt,
                max_tokens: req.max_tokens,
                seed: req.seed,
            });
            match rx.recv() {
                Ok(resp) => (
                    200,
                    completion_response(id, &resp.text, resp.tokens.len(), resp.ttft, resp.latency),
                ),
                Err(_) => (500, error_response("engine dropped the request")),
            }
        }
        _ => (404, error_response("not found")),
    }
}

fn respond(mut stream: TcpStream, status: u16, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        _ => "Internal Server Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}
