//! Global per-stage queues with condvar wakeups, byte-accounted
//! migrations, the live role registry the monitor thread reads, and the
//! process-wide cross-request encoder cache (shared here because both the
//! submit path and the instance threads touch it).

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::cache::EncoderCache;
use crate::core::request::RequestId;
use crate::core::stage::Stage;

use super::job::Job;
use super::supervise::{lock_clean, Supervision};

/// MM tokens per encoder-cache block on the engine side. Tiny-lmm's
/// encoder emits 16 MM tokens per tile (`TinyConfig::vis_out_tokens`),
/// so one block holds one tile's output.
pub const ENCODER_CACHE_BLOCK_TOKENS: u32 = 16;

/// Transfer byte counters (EP and PD migrations).
#[derive(Debug, Default)]
pub struct TransferStats {
    pub ep_bytes: AtomicU64,
    pub ep_count: AtomicU64,
    pub pd_bytes: AtomicU64,
    pub pd_count: AtomicU64,
}

/// Ordered reassembly of a streamed payload split into indexed parts.
/// Used on *both* streamed edges: the prefill side reassembles EP chunks
/// (chunked handoff, `EpdConfig::ep_chunk_tokens > 0`,
/// [`StageQueues::reassembly`]) and the decode side reassembles PD KV
/// layer groups (`EpdConfig::pd_layer_groups > 0`,
/// [`StageQueues::kv_reassembly`]). Parts complete in arbitrary order
/// across instances; the buffer slots each partial payload by part index
/// and releases the request only when every part has landed —
/// concatenated **in part order**, so the merged payload is
/// byte-identical to the monolithic payload regardless of arrival order
/// (property-tested in `rust/tests/property_streaming.rs` and
/// `rust/tests/property_pd_streaming.rs`).
#[derive(Debug, Default)]
pub struct ReassemblyBuffer {
    inner: Mutex<HashMap<RequestId, Reassembly>>,
}

#[derive(Debug)]
struct Reassembly {
    parts: Vec<Option<Vec<f32>>>,
    arrived: usize,
}

impl ReassemblyBuffer {
    pub fn new() -> ReassemblyBuffer {
        ReassemblyBuffer::default()
    }

    /// Register a request expecting `parts` streamed shards. Must be
    /// called before the first chunk can arrive (i.e. before the encode
    /// jobs are enqueued). Idempotent for the same part count.
    pub fn expect(&self, id: RequestId, parts: usize) {
        assert!(parts > 0, "reassembly needs at least one part");
        let mut g = lock_clean(&self.inner);
        let e = g
            .entry(id)
            .or_insert_with(|| Reassembly { parts: vec![None; parts], arrived: 0 });
        assert_eq!(e.parts.len(), parts, "conflicting part count for req {id}");
    }

    /// Slot one shard's tokens. Returns the in-order merged payload when
    /// this was the final outstanding part (the request's reassembly state
    /// is dropped), `None` while parts are still missing.
    ///
    /// A chunk for an id with no registered reassembly is dropped with
    /// `None`: a sibling shard's encode failure aborts the request
    /// ([`Self::abort`]) while this shard's chunk may already sit in — or
    /// still be headed for — the prefill queue, in either order.
    ///
    /// # Panics
    /// On duplicate shard indices for a registered request — a caller bug
    /// that must not be absorbed silently.
    pub fn insert(&self, id: RequestId, shard: usize, mm: Vec<f32>) -> Option<Vec<f32>> {
        // Hold the lock only for the slotting; the O(payload) merge of the
        // final chunk happens outside it so concurrent workers' inserts
        // for other requests never serialize behind a large memcpy.
        let complete = {
            let mut g = lock_clean(&self.inner);
            let Some(e) = g.get_mut(&id) else {
                return None; // aborted request: drop the orphan chunk
            };
            assert!(e.parts[shard].is_none(), "duplicate shard {shard} for req {id}");
            e.parts[shard] = Some(mm);
            e.arrived += 1;
            if e.arrived < e.parts.len() {
                return None;
            }
            g.remove(&id)?
        };
        let mut merged = Vec::with_capacity(
            complete.parts.iter().map(|p| p.as_ref().map_or(0, Vec::len)).sum(),
        );
        for p in complete.parts.into_iter().flatten() {
            merged.extend_from_slice(&p);
        }
        Some(merged)
    }

    /// Drop a request's partial state (abort/cancel path). Returns whether
    /// anything was pending.
    pub fn abort(&self, id: RequestId) -> bool {
        lock_clean(&self.inner).remove(&id).is_some()
    }

    /// Requests with outstanding parts.
    pub fn pending(&self) -> usize {
        lock_clean(&self.inner).len()
    }
}

/// The shared queue fabric.
pub struct StageQueues {
    encode: Mutex<VecDeque<Job>>,
    prefill: Mutex<VecDeque<Job>>,
    decode: Mutex<VecDeque<Job>>,
    cv: Condvar,
    /// Paired with `cv` for waits that span all queues.
    wait_lock: Mutex<()>,
    pub shutdown: AtomicBool,
    pub transfers: TransferStats,
    /// Current role of each instance (monitor + IRP fan-out read this).
    pub roles: Mutex<Vec<Stage>>,
    /// Cross-request content-addressed encoder cache: submit consults it
    /// (hit → straight to prefill), instance threads populate it when the
    /// last IRP shard merges (or, under streaming, when reassembly
    /// completes at the prefill side).
    pub encoder_cache: Mutex<EncoderCache>,
    /// Prefill-side reassembly of streamed EP chunks.
    pub reassembly: ReassemblyBuffer,
    /// Decode-side reassembly of streamed PD KV layer groups. A separate
    /// buffer (not another use of `reassembly`) because a request id can
    /// in principle have both edges streaming, and the two payloads must
    /// never mix.
    pub kv_reassembly: ReassemblyBuffer,
    /// Supervision state: heartbeats, liveness, the ownership ledger, the
    /// retry queue, the deadline watchdog, and the drain flag. Disabled
    /// (all no-ops) unless the engine was started with
    /// `EpdConfig::supervise` or a drain timeout.
    pub supervision: Supervision,
}

impl StageQueues {
    pub fn new(initial_roles: Vec<Stage>) -> StageQueues {
        // Default capacity matches `EpdConfig::epd`'s encoder_cache_tokens
        // default (1 Mi MM tokens); the engine passes the configured value
        // through `with_encoder_cache`.
        StageQueues::with_encoder_cache(initial_roles, 1 << 20)
    }

    /// Like [`StageQueues::new`] with an explicit encoder-cache capacity
    /// in MM tokens (0 disables cross-request reuse).
    pub fn with_encoder_cache(initial_roles: Vec<Stage>, cache_tokens: u64) -> StageQueues {
        let n = initial_roles.len();
        StageQueues::with_supervision(initial_roles, cache_tokens, Supervision::disabled(n))
    }

    /// Full constructor: explicit encoder-cache capacity and supervision
    /// state (the engine resolves both from `EpdConfig`).
    pub fn with_supervision(
        initial_roles: Vec<Stage>,
        cache_tokens: u64,
        supervision: Supervision,
    ) -> StageQueues {
        StageQueues {
            encode: Mutex::new(VecDeque::new()),
            prefill: Mutex::new(VecDeque::new()),
            decode: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            wait_lock: Mutex::new(()),
            shutdown: AtomicBool::new(false),
            transfers: TransferStats::default(),
            roles: Mutex::new(initial_roles),
            encoder_cache: Mutex::new(EncoderCache::with_capacity_tokens(
                cache_tokens,
                ENCODER_CACHE_BLOCK_TOKENS,
            )),
            reassembly: ReassemblyBuffer::new(),
            kv_reassembly: ReassemblyBuffer::new(),
            supervision,
        }
    }

    fn queue(&self, stage: Stage) -> &Mutex<VecDeque<Job>> {
        match stage {
            Stage::Encode => &self.encode,
            Stage::Prefill => &self.prefill,
            Stage::Decode => &self.decode,
        }
    }

    /// Push a job to a stage queue and wake pollers.
    pub fn push(&self, stage: Stage, job: Job) {
        lock_clean(self.queue(stage)).push_back(job);
        self.cv.notify_all();
    }

    /// Record an EP migration's bytes (the mm vector really moved between
    /// instance runtimes through this queue).
    pub fn account_ep(&self, bytes: usize) {
        self.transfers.ep_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.transfers.ep_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn account_pd(&self, bytes: usize) {
        self.transfers.pd_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        self.transfers.pd_count.fetch_add(1, Ordering::Relaxed);
    }

    /// Pop one job from the first non-empty stage in `stages` (priority
    /// order). Returns immediately.
    pub fn try_pop(&self, stages: &[Stage]) -> Option<Job> {
        for &s in stages {
            if let Some(j) = lock_clean(self.queue(s)).pop_front() {
                return Some(j);
            }
        }
        None
    }

    /// Pop up to `n` decode jobs at once (batch forming).
    pub fn pop_decode_batch(&self, n: usize) -> Vec<Job> {
        let mut q = lock_clean(&self.decode);
        let take = n.min(q.len());
        q.drain(..take).collect()
    }

    /// Blocking pop with timeout across the given stages.
    pub fn pop_timeout(&self, stages: &[Stage], timeout: Duration) -> Option<Job> {
        if let Some(j) = self.try_pop(stages) {
            return Some(j);
        }
        let guard = lock_clean(&self.wait_lock);
        let _unused = self
            .cv
            .wait_timeout(guard, timeout)
            .unwrap_or_else(|e| e.into_inner());
        self.try_pop(stages)
    }

    pub fn len(&self, stage: Stage) -> usize {
        lock_clean(self.queue(stage)).len()
    }

    pub fn begin_shutdown(&self) {
        self.shutdown.store(true, Ordering::SeqCst);
        self.cv.notify_all();
    }

    pub fn is_shutdown(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst)
    }

    /// *Alive* instances currently serving `stage`: crashed workers stop
    /// counting toward IRP fan-out and the router's capacity outlook.
    /// (With supervision off nothing marks instances dead, so this is
    /// exactly the pre-supervision role count.)
    pub fn role_count(&self, stage: Stage) -> u32 {
        lock_clean(&self.roles)
            .iter()
            .enumerate()
            .filter(|&(i, &r)| r == stage && self.supervision.is_alive(i))
            .count() as u32
    }

    /// A point-in-time copy of the role registry.
    pub fn roles_snapshot(&self) -> Vec<Stage> {
        lock_clean(&self.roles).clone()
    }

    pub fn set_role(&self, idx: usize, role: Stage) {
        lock_clean(&self.roles)[idx] = role;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::job::ReqCtx;
    use std::sync::mpsc::sync_channel;
    use std::sync::Arc;

    fn dummy_job() -> Job {
        let (tx, _rx) = sync_channel(1);
        Job::Prefill {
            ctx: Arc::new(ReqCtx::new(0, 0, vec![], 1, None, 1, tx)),
            mm: Arc::new(vec![]),
        }
    }

    #[test]
    fn push_pop_priority() {
        let q = StageQueues::new(vec![Stage::Encode]);
        q.push(Stage::Decode, dummy_job());
        q.push(Stage::Encode, dummy_job());
        // Priority order: encode first.
        let got = q.try_pop(&[Stage::Encode, Stage::Decode]).unwrap();
        assert!(matches!(got, Job::Prefill { .. }));
        assert_eq!(q.len(Stage::Encode), 0);
        assert_eq!(q.len(Stage::Decode), 1);
    }

    #[test]
    fn pop_timeout_times_out_empty() {
        let q = StageQueues::new(vec![]);
        let t0 = std::time::Instant::now();
        assert!(q.pop_timeout(&[Stage::Encode], Duration::from_millis(30)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn decode_batch_pop() {
        let q = StageQueues::new(vec![]);
        for _ in 0..5 {
            q.push(Stage::Decode, dummy_job());
        }
        assert_eq!(q.pop_decode_batch(3).len(), 3);
        assert_eq!(q.pop_decode_batch(8).len(), 2);
    }

    #[test]
    fn role_registry() {
        let q = StageQueues::new(vec![Stage::Encode, Stage::Encode, Stage::Decode]);
        assert_eq!(q.role_count(Stage::Encode), 2);
        q.set_role(0, Stage::Decode);
        assert_eq!(q.role_count(Stage::Encode), 1);
        assert_eq!(q.role_count(Stage::Decode), 2);
    }

    #[test]
    fn encoder_cache_shared_through_fabric() {
        let q = StageQueues::with_encoder_cache(vec![], 1024);
        {
            let mut c = q.encoder_cache.lock().unwrap();
            assert!(c.insert_pinned(42, 64, Some(Arc::new(vec![0.5f32; 64]))));
            c.unpin(42);
        }
        let mut c = q.encoder_cache.lock().unwrap();
        assert_eq!(c.lookup_pin(42), Some(64));
        assert_eq!(c.payload(42).unwrap().len(), 64);
        c.unpin(42);
    }

    #[test]
    fn reassembly_out_of_order_merges_in_order() {
        let rb = ReassemblyBuffer::new();
        rb.expect(7, 3);
        assert_eq!(rb.pending(), 1);
        assert!(rb.insert(7, 2, vec![5.0, 6.0]).is_none());
        assert!(rb.insert(7, 0, vec![1.0, 2.0]).is_none());
        let merged = rb.insert(7, 1, vec![3.0, 4.0]).unwrap();
        assert_eq!(merged, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(rb.pending(), 0, "completed request dropped");
    }

    #[test]
    fn reassembly_abort_clears_partial_state() {
        let rb = ReassemblyBuffer::new();
        rb.expect(1, 2);
        assert!(rb.insert(1, 0, vec![1.0]).is_none());
        assert!(rb.abort(1));
        assert!(!rb.abort(1));
        assert_eq!(rb.pending(), 0);
    }

    #[test]
    #[should_panic(expected = "duplicate shard")]
    fn reassembly_duplicate_chunk_panics() {
        let rb = ReassemblyBuffer::new();
        rb.expect(1, 2);
        rb.insert(1, 0, vec![1.0]);
        rb.insert(1, 0, vec![1.0]);
    }

    #[test]
    fn reassembly_orphan_chunk_after_abort_is_dropped() {
        // A sibling shard's encode failure aborts the request; this
        // shard's already-queued chunk must be dropped, not panic the
        // prefill worker — in either abort/insert order.
        let rb = ReassemblyBuffer::new();
        rb.expect(3, 2);
        rb.abort(3);
        assert!(rb.insert(3, 1, vec![1.0]).is_none());
        assert!(rb.insert(99, 0, vec![1.0]).is_none(), "never-registered id");
        assert_eq!(rb.pending(), 0);
    }

    #[test]
    fn transfer_accounting() {
        let q = StageQueues::new(vec![]);
        q.account_ep(1024);
        q.account_ep(1024);
        q.account_pd(4096);
        assert_eq!(q.transfers.ep_bytes.load(Ordering::Relaxed), 2048);
        assert_eq!(q.transfers.ep_count.load(Ordering::Relaxed), 2);
        assert_eq!(q.transfers.pd_bytes.load(Ordering::Relaxed), 4096);
    }
}
