//! The real serving engine: threaded E/P/D instances executing the
//! tiny-LMM artifacts on PJRT, wired together by the coordinator policies.
//!
//! Each instance is an OS thread owning its own [`TinyLmmRuntime`]
//! (PJRT client + compiled executables — its "GPU"). Stage hand-offs go
//! through global per-stage queues (§3.2's "between different stages,
//! global queues are used, and each available engine pulls proactively").
//! EP and PD migrations move the actual token/KV bytes between instance-
//! owned runtimes; IRP shards a request's tiles across encode instances;
//! a monitor thread drives dynamic role switching.
//!
//! [`crate::runtime::TinyLmmRuntime`] is deliberately *not* `Send` (the
//! `xla` client is `Rc`-based), so every runtime is created inside its
//! instance thread and never crosses threads; queues carry plain `Vec<f32>`
//! tensors.

pub mod job;
pub mod queues;
pub mod instance;
pub mod serve;
pub mod http;

pub use job::{GenRequest, GenResponse};
pub use serve::{EngineConfig, EpdEngine};
