//! The real serving engine: threaded E/P/D instances executing the
//! tiny-LMM artifacts on PJRT, wired together by the coordinator policies.
//!
//! Each instance is an OS thread owning its own [`TinyLmmRuntime`]
//! (PJRT client + compiled executables — its "GPU"). Stage hand-offs go
//! through global per-stage queues (§3.2's "between different stages,
//! global queues are used, and each available engine pulls proactively").
//! EP and PD migrations move the actual token/KV bytes between instance-
//! owned runtimes; IRP shards a request's tiles across encode instances;
//! a monitor thread drives dynamic role switching and — with
//! `supervise = true` — worker supervision: heartbeat tracking,
//! crash-event sweeps, exactly-once redispatch of in-flight work, and
//! per-request deadline enforcement (see [`supervise`]).
//!
//! [`crate::runtime::TinyLmmRuntime`] is deliberately *not* `Send` (the
//! `xla` client is `Rc`-based), so every runtime is created inside its
//! instance thread and never crosses threads; queues carry plain `Vec<f32>`
//! tensors.
//!
//! Fallibility discipline: the engine's hot paths never `unwrap`/`expect`
//! (lint-enforced below) — runtime errors propagate into the supervision
//! layer as typed recoveries or structured [`job::GenResponse::Failed`]
//! responses, and poisoned locks are taken over via
//! [`supervise::lock_clean`] instead of cascading the panic.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod job;
pub mod queues;
pub mod instance;
pub mod serve;
pub mod http;
pub mod supervise;

pub use job::{FailReason, GenFailure, GenOutput, GenRequest, GenResponse};
pub use serve::{EngineConfig, EpdEngine};
pub use supervise::EngineFaultPlan;
