//! Engine job types and the per-request shared context.

use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::Mutex;
use std::time::Instant;

use crate::core::request::{Priority, RequestId};

/// A generation request submitted to the engine.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: RequestId,
    /// Synthetic images attached (each is one encoder tile for tiny-lmm).
    pub images: u32,
    pub prompt: String,
    pub max_tokens: u32,
    /// Seed for the synthetic image content.
    pub seed: u64,
    /// Tenant id for front-door fairness accounting (0 = default).
    pub tenant: u32,
    /// Priority class, consulted by front-door admission.
    pub class: Priority,
}

/// The completed response.
#[derive(Debug, Clone)]
pub struct GenResponse {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub text: String,
    /// Seconds from submit to first token.
    pub ttft: f64,
    /// Seconds from submit to completion.
    pub latency: f64,
}

/// Shared per-request state, referenced by every job of the request.
pub struct ReqCtx {
    pub id: RequestId,
    pub images: u32,
    pub text_tokens: Vec<i32>,
    pub max_tokens: u32,
    pub arrival: Instant,
    /// Content address of the request's media (None for text-only):
    /// the cross-request encoder-cache key. Hits skip encode at submit;
    /// misses populate the cache when the last shard merges.
    pub media_hash: Option<u64>,
    pub shards_total: u32,
    shards_done: AtomicU32,
    /// MM token shards, indexed by shard number, merged when all arrive
    /// (§3.2.2's align-and-merge at the prefill side).
    pub mm_parts: Mutex<Vec<Option<Vec<f32>>>>,
    pub done_tx: SyncSender<GenResponse>,
}

impl ReqCtx {
    pub fn new(
        id: RequestId,
        images: u32,
        text_tokens: Vec<i32>,
        max_tokens: u32,
        media_hash: Option<u64>,
        shards_total: u32,
        done_tx: SyncSender<GenResponse>,
    ) -> ReqCtx {
        ReqCtx {
            id,
            images,
            text_tokens,
            max_tokens,
            arrival: Instant::now(),
            media_hash,
            shards_total,
            shards_done: AtomicU32::new(0),
            mm_parts: Mutex::new(vec![None; shards_total as usize]),
            done_tx,
        }
    }

    /// Record one finished shard; returns true when this was the last.
    pub fn shard_done(&self, shard: usize, mm: Vec<f32>) -> bool {
        {
            let mut parts = self.mm_parts.lock().unwrap();
            assert!(parts[shard].is_none(), "duplicate shard {shard}");
            parts[shard] = Some(mm);
        }
        let done = self.shards_done.fetch_add(1, Ordering::SeqCst) + 1;
        done == self.shards_total
    }

    /// Merge shards in order (call only after the last `shard_done`).
    pub fn merged_mm(&self) -> Vec<f32> {
        let parts = self.mm_parts.lock().unwrap();
        let mut out = Vec::new();
        for p in parts.iter() {
            out.extend_from_slice(p.as_ref().expect("missing shard"));
        }
        out
    }
}

/// Work items flowing through the stage queues.
pub enum Job {
    /// One IRP shard of a request's tiles.
    Encode {
        ctx: std::sync::Arc<ReqCtx>,
        shard: usize,
        /// Flattened `[tiles, num_patches, patch_dim]`.
        patches: Vec<f32>,
        tiles: u32,
        /// Chunked EP streaming (`EpdConfig::ep_chunk_tokens > 0`): emit
        /// this shard's tokens to the prefill side as soon as they exist
        /// instead of merging on the last shard; reassembly happens in
        /// [`super::queues::ReassemblyBuffer`] at the prefill side.
        stream: bool,
    },
    /// A request whose MM tokens arrived at the prefill side. The tokens
    /// are shared (`Arc`) so an encoder-cache entry and any number of
    /// hit-path prefill jobs reference one buffer without copying.
    Prefill {
        ctx: std::sync::Arc<ReqCtx>,
        mm: std::sync::Arc<Vec<f32>>,
    },
    /// A partial EP payload: one streamed shard's MM tokens, headed for
    /// the prefill-side reassembly buffer. The prefill worker that
    /// completes a request's reassembly runs its prefill immediately.
    PrefillChunk {
        ctx: std::sync::Arc<ReqCtx>,
        shard: usize,
        mm: Vec<f32>,
    },
    /// A prefilled request migrating to decode.
    Decode {
        ctx: std::sync::Arc<ReqCtx>,
        kv: Vec<f32>,
        len: i32,
        /// Next input token (the first generated token).
        next_token: i32,
        generated: Vec<i32>,
    },
    /// One layer group of a prefilled request's KV cache, streamed to the
    /// decode side (`EpdConfig::pd_layer_groups > 0`). Groups are
    /// contiguous spans of the flat KV buffer (layer-aligned when the
    /// group count divides the layer count) and reassemble in
    /// [`super::queues::StageQueues::kv_reassembly`]; the decode worker
    /// that slots the final group admits the request to its continuous
    /// batch with the byte-identical reconstructed KV.
    KvChunk {
        ctx: std::sync::Arc<ReqCtx>,
        group: usize,
        kv: Vec<f32>,
        len: i32,
        /// Next input token (the first generated token).
        next_token: i32,
    },
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn shard_accounting() {
        let (tx, _rx) = sync_channel(1);
        let ctx = ReqCtx::new(1, 2, vec![256], 4, None, 3, tx);
        assert!(!ctx.shard_done(0, vec![1.0]));
        assert!(!ctx.shard_done(2, vec![3.0]));
        assert!(ctx.shard_done(1, vec![2.0]));
        assert_eq!(ctx.merged_mm(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "duplicate shard")]
    fn duplicate_shard_panics() {
        let (tx, _rx) = sync_channel(1);
        let ctx = ReqCtx::new(1, 1, vec![], 1, None, 2, tx);
        ctx.shard_done(0, vec![]);
        ctx.shard_done(0, vec![]);
    }
}
