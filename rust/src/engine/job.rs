//! Engine job types and the per-request shared context.

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::mpsc::SyncSender;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::api::ApiError;
use crate::core::request::{Priority, RequestId};
use crate::core::stage::Stage;

use super::supervise::lock_clean;

/// A generation request submitted to the engine.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: RequestId,
    /// Synthetic images attached (each is one encoder tile for tiny-lmm).
    pub images: u32,
    pub prompt: String,
    pub max_tokens: u32,
    /// Seed for the synthetic image content.
    pub seed: u64,
    /// Tenant id for front-door fairness accounting (0 = default).
    pub tenant: u32,
    /// Priority class, consulted by front-door admission.
    pub class: Priority,
    /// End-to-end deadline in ms (0 = none). Enforced at every stage
    /// boundary and by the supervisor's watchdog.
    pub deadline_ms: u64,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct GenOutput {
    pub id: RequestId,
    pub tokens: Vec<i32>,
    pub text: String,
    /// Seconds from submit to first token.
    pub ttft: f64,
    /// Seconds from submit to completion.
    pub latency: f64,
}

/// Why a request failed terminally.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FailReason {
    /// The owning worker died and recovery budget was exhausted (or no
    /// same-kind sibling exists to re-dispatch to).
    WorkerLost,
    /// The request's `deadline_ms` elapsed before completion.
    DeadlineExceeded,
    /// The engine was draining at submit time, or the drain timeout
    /// expired with the request still in flight.
    Draining,
    /// A runtime-level stage error (encode/prefill/decode) that retries
    /// did not absorb.
    Runtime(String),
}

impl FailReason {
    /// Stable machine-readable code (matches `ApiError::code`).
    pub fn code(&self) -> &'static str {
        match self {
            FailReason::WorkerLost => "worker_lost",
            FailReason::DeadlineExceeded => "deadline_exceeded",
            FailReason::Draining => "draining",
            FailReason::Runtime(_) => "runtime_error",
        }
    }

    /// HTTP status the failure maps to at the front door.
    pub fn http_status(&self) -> u16 {
        match self {
            FailReason::WorkerLost | FailReason::Draining => 503,
            FailReason::DeadlineExceeded => 504,
            FailReason::Runtime(_) => 500,
        }
    }
}

/// A typed terminal failure (the supervised alternative to a dropped
/// sender: receivers always observe exactly one response).
#[derive(Debug, Clone)]
pub struct GenFailure {
    pub id: RequestId,
    pub reason: FailReason,
    /// Redispatch attempts consumed before the request terminated.
    pub retries: u32,
    /// Seconds from submit to the failure.
    pub latency: f64,
}

impl GenFailure {
    /// Lower to the front-door error shape. `deadline_ms` fills the 504
    /// message; `retry_after_ms` is the client backoff hint.
    pub fn to_api_error(&self, deadline_ms: u64, retry_after_ms: u64) -> ApiError {
        match &self.reason {
            FailReason::WorkerLost => ApiError::worker_lost(retry_after_ms),
            FailReason::DeadlineExceeded => ApiError::deadline_exceeded(deadline_ms, retry_after_ms),
            FailReason::Draining => ApiError::draining(retry_after_ms),
            FailReason::Runtime(msg) => ApiError::internal(msg.clone()),
        }
    }
}

impl std::fmt::Display for GenFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request {} failed: {} after {} retries", self.id, self.reason.code(), self.retries)
    }
}

impl std::error::Error for GenFailure {}

/// The response delivered on a request's channel: exactly one per
/// request — a completion or a typed failure, never a silent drop.
#[derive(Debug, Clone)]
pub enum GenResponse {
    Done(GenOutput),
    Failed(GenFailure),
}

impl GenResponse {
    pub fn id(&self) -> RequestId {
        match self {
            GenResponse::Done(o) => o.id,
            GenResponse::Failed(f) => f.id,
        }
    }

    pub fn is_failed(&self) -> bool {
        matches!(self, GenResponse::Failed(_))
    }

    /// Unwrap into a result for callers that treat failure as an error.
    pub fn output(self) -> Result<GenOutput, GenFailure> {
        match self {
            GenResponse::Done(o) => Ok(o),
            GenResponse::Failed(f) => Err(f),
        }
    }
}

/// Shared per-request state, referenced by every job of the request.
pub struct ReqCtx {
    pub id: RequestId,
    pub images: u32,
    pub text_tokens: Vec<i32>,
    pub max_tokens: u32,
    pub arrival: Instant,
    /// Content address of the request's media (None for text-only):
    /// the cross-request encoder-cache key. Hits skip encode at submit;
    /// misses populate the cache when the last shard merges.
    pub media_hash: Option<u64>,
    pub shards_total: u32,
    shards_done: AtomicU32,
    /// MM token shards, indexed by shard number, merged when all arrive
    /// (§3.2.2's align-and-merge at the prefill side).
    pub mm_parts: Mutex<Vec<Option<Vec<f32>>>>,
    pub done_tx: SyncSender<GenResponse>,
    /// Seed of the synthetic media payload — recovery re-encodes from it.
    pub seed: u64,
    /// End-to-end deadline in ms (0 = none).
    pub deadline_ms: u64,
    /// Exactly-once termination latch, shared across epochs
    /// ([`ReqCtx::respawn`]): whichever of finish / fail wins the CAS
    /// sends the single response.
    terminated: Arc<AtomicBool>,
    /// This epoch was superseded (monolithic fallback) or failed — stage
    /// boundaries skip its queued jobs.
    cancelled: AtomicBool,
    /// Redispatch attempts, shared across epochs.
    retries: Arc<AtomicU32>,
}

impl ReqCtx {
    pub fn new(
        id: RequestId,
        images: u32,
        text_tokens: Vec<i32>,
        max_tokens: u32,
        media_hash: Option<u64>,
        shards_total: u32,
        done_tx: SyncSender<GenResponse>,
    ) -> ReqCtx {
        ReqCtx {
            id,
            images,
            text_tokens,
            max_tokens,
            arrival: Instant::now(),
            media_hash,
            shards_total,
            shards_done: AtomicU32::new(0),
            mm_parts: Mutex::new(vec![None; shards_total as usize]),
            done_tx,
            seed: 0,
            deadline_ms: 0,
            terminated: Arc::new(AtomicBool::new(false)),
            cancelled: AtomicBool::new(false),
            retries: Arc::new(AtomicU32::new(0)),
        }
    }

    /// Attach the media seed (recovery re-encodes from it).
    pub fn with_seed(mut self, seed: u64) -> ReqCtx {
        self.seed = seed;
        self
    }

    /// Attach an end-to-end deadline in ms (0 = none).
    pub fn with_deadline_ms(mut self, deadline_ms: u64) -> ReqCtx {
        self.deadline_ms = deadline_ms;
        self
    }

    /// Record one finished shard; returns true when this was the last.
    pub fn shard_done(&self, shard: usize, mm: Vec<f32>) -> bool {
        {
            let mut parts = lock_clean(&self.mm_parts);
            assert!(parts[shard].is_none(), "duplicate shard {shard}");
            parts[shard] = Some(mm);
        }
        let done = self.shards_done.fetch_add(1, Ordering::SeqCst) + 1;
        done == self.shards_total
    }

    /// Merge shards in order (call only after the last `shard_done`).
    pub fn merged_mm(&self) -> Vec<f32> {
        let parts = lock_clean(&self.mm_parts);
        let mut out = Vec::new();
        for p in parts.iter() {
            debug_assert!(p.is_some(), "missing shard");
            if let Some(p) = p {
                out.extend_from_slice(p);
            }
        }
        out
    }

    /// Win the exactly-once termination race: true for the single caller
    /// allowed to send the request's response.
    pub fn try_terminate(&self) -> bool {
        !self.terminated.swap(true, Ordering::SeqCst)
    }

    pub fn is_terminated(&self) -> bool {
        self.terminated.load(Ordering::SeqCst)
    }

    /// Mark this epoch superseded; stage boundaries skip its jobs.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }

    /// Whether the request's deadline has elapsed (false when none set).
    pub fn past_deadline(&self) -> bool {
        self.deadline_ms > 0 && self.arrival.elapsed().as_millis() as u64 > self.deadline_ms
    }

    /// Whether `deadline + grace` has elapsed (the watchdog's bound).
    pub fn past_deadline_with_grace(&self, grace_ms: u64) -> bool {
        self.deadline_ms > 0
            && self.arrival.elapsed().as_millis() as u64 > self.deadline_ms.saturating_add(grace_ms)
    }

    /// Count one redispatch attempt; returns the new (1-based) total.
    pub fn note_retry(&self) -> u32 {
        self.retries.fetch_add(1, Ordering::SeqCst) + 1
    }

    pub fn retry_count(&self) -> u32 {
        self.retries.load(Ordering::SeqCst)
    }

    /// Start a fresh epoch of this request (the monolithic-fallback
    /// path): same id, channel, arrival clock, termination latch, and
    /// retry budget — fresh shard accounting. The current epoch is
    /// cancelled so its still-queued jobs are skipped at stage
    /// boundaries.
    pub fn respawn(&self, shards_total: u32) -> Arc<ReqCtx> {
        self.cancel();
        Arc::new(ReqCtx {
            id: self.id,
            images: self.images,
            text_tokens: self.text_tokens.clone(),
            max_tokens: self.max_tokens,
            arrival: self.arrival,
            media_hash: self.media_hash,
            shards_total,
            shards_done: AtomicU32::new(0),
            mm_parts: Mutex::new(vec![None; shards_total as usize]),
            done_tx: self.done_tx.clone(),
            seed: self.seed,
            deadline_ms: self.deadline_ms,
            terminated: Arc::clone(&self.terminated),
            cancelled: AtomicBool::new(false),
            retries: Arc::clone(&self.retries),
        })
    }
}

/// Work items flowing through the stage queues. `Clone` exists for the
/// supervision ledger's snapshots (payload vectors copy; `ctx` is
/// shared), not for general fan-out.
#[derive(Clone)]
pub enum Job {
    /// One IRP shard of a request's tiles.
    Encode {
        ctx: Arc<ReqCtx>,
        shard: usize,
        /// Flattened `[tiles, num_patches, patch_dim]`.
        patches: Vec<f32>,
        tiles: u32,
        /// Chunked EP streaming (`EpdConfig::ep_chunk_tokens > 0`): emit
        /// this shard's tokens to the prefill side as soon as they exist
        /// instead of merging on the last shard; reassembly happens in
        /// [`super::queues::ReassemblyBuffer`] at the prefill side.
        stream: bool,
    },
    /// A request whose MM tokens arrived at the prefill side. The tokens
    /// are shared (`Arc`) so an encoder-cache entry and any number of
    /// hit-path prefill jobs reference one buffer without copying.
    Prefill {
        ctx: Arc<ReqCtx>,
        mm: Arc<Vec<f32>>,
    },
    /// A partial EP payload: one streamed shard's MM tokens, headed for
    /// the prefill-side reassembly buffer. The prefill worker that
    /// completes a request's reassembly runs its prefill immediately.
    PrefillChunk {
        ctx: Arc<ReqCtx>,
        shard: usize,
        mm: Vec<f32>,
    },
    /// A prefilled request migrating to decode.
    Decode {
        ctx: Arc<ReqCtx>,
        kv: Vec<f32>,
        len: i32,
        /// Next input token (the first generated token).
        next_token: i32,
        generated: Vec<i32>,
    },
    /// One layer group of a prefilled request's KV cache, streamed to the
    /// decode side (`EpdConfig::pd_layer_groups > 0`). Groups are
    /// contiguous spans of the flat KV buffer (layer-aligned when the
    /// group count divides the layer count) and reassemble in
    /// [`super::queues::StageQueues::kv_reassembly`]; the decode worker
    /// that slots the final group admits the request to its continuous
    /// batch with the byte-identical reconstructed KV.
    KvChunk {
        ctx: Arc<ReqCtx>,
        group: usize,
        kv: Vec<f32>,
        len: i32,
        /// Next input token (the first generated token).
        next_token: i32,
    },
}

impl Job {
    /// The request this job belongs to.
    pub fn ctx(&self) -> &Arc<ReqCtx> {
        match self {
            Job::Encode { ctx, .. }
            | Job::Prefill { ctx, .. }
            | Job::PrefillChunk { ctx, .. }
            | Job::Decode { ctx, .. }
            | Job::KvChunk { ctx, .. } => ctx,
        }
    }

    /// The stage a popped job's work is accounted to — the worker-side
    /// busy/service counters the monitor's load signals are built from,
    /// and the queue a re-dispatched job is pushed back onto.
    pub fn stage(&self) -> Stage {
        match self {
            Job::Encode { .. } => Stage::Encode,
            Job::PrefillChunk { .. } | Job::Prefill { .. } => Stage::Prefill,
            Job::Decode { .. } | Job::KvChunk { .. } => Stage::Decode,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::sync_channel;

    #[test]
    fn shard_accounting() {
        let (tx, _rx) = sync_channel(1);
        let ctx = ReqCtx::new(1, 2, vec![256], 4, None, 3, tx);
        assert!(!ctx.shard_done(0, vec![1.0]));
        assert!(!ctx.shard_done(2, vec![3.0]));
        assert!(ctx.shard_done(1, vec![2.0]));
        assert_eq!(ctx.merged_mm(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "duplicate shard")]
    fn duplicate_shard_panics() {
        let (tx, _rx) = sync_channel(1);
        let ctx = ReqCtx::new(1, 1, vec![], 1, None, 2, tx);
        ctx.shard_done(0, vec![]);
        ctx.shard_done(0, vec![]);
    }

    #[test]
    fn termination_latch_is_exactly_once() {
        let (tx, _rx) = sync_channel(1);
        let ctx = ReqCtx::new(1, 0, vec![], 1, None, 1, tx);
        assert!(!ctx.is_terminated());
        assert!(ctx.try_terminate());
        assert!(!ctx.try_terminate(), "second terminator loses the race");
        assert!(ctx.is_terminated());
    }

    #[test]
    fn respawn_shares_latch_and_budget() {
        let (tx, _rx) = sync_channel(1);
        let ctx = Arc::new(ReqCtx::new(7, 2, vec![3], 4, Some(9), 3, tx).with_seed(0xA).with_deadline_ms(500));
        ctx.note_retry();
        let fresh = ctx.respawn(1);
        assert!(ctx.is_cancelled(), "old epoch superseded");
        assert!(!fresh.is_cancelled());
        assert_eq!(fresh.id, 7);
        assert_eq!(fresh.shards_total, 1);
        assert_eq!(fresh.seed, 0xA);
        assert_eq!(fresh.deadline_ms, 500);
        assert_eq!(fresh.retry_count(), 1, "retry budget shared");
        assert!(fresh.try_terminate());
        assert!(!ctx.try_terminate(), "latch shared across epochs");
    }

    #[test]
    fn deadline_checks() {
        let (tx, _rx) = sync_channel(1);
        let ctx = ReqCtx::new(1, 0, vec![], 1, None, 1, tx);
        assert!(!ctx.past_deadline(), "no deadline set");
        let (tx, _rx) = sync_channel(1);
        let ctx = ReqCtx::new(1, 0, vec![], 1, None, 1, tx).with_deadline_ms(5);
        std::thread::sleep(std::time::Duration::from_millis(15));
        assert!(ctx.past_deadline());
        assert!(ctx.past_deadline_with_grace(5));
        assert!(!ctx.past_deadline_with_grace(10_000));
    }

    #[test]
    fn fail_reason_codes_and_statuses() {
        assert_eq!(FailReason::WorkerLost.code(), "worker_lost");
        assert_eq!(FailReason::WorkerLost.http_status(), 503);
        assert_eq!(FailReason::DeadlineExceeded.code(), "deadline_exceeded");
        assert_eq!(FailReason::DeadlineExceeded.http_status(), 504);
        assert_eq!(FailReason::Draining.http_status(), 503);
        assert_eq!(FailReason::Runtime("x".into()).http_status(), 500);
    }

    #[test]
    fn response_accessors() {
        let done = GenResponse::Done(GenOutput {
            id: 3,
            tokens: vec![1],
            text: "t".into(),
            ttft: 0.1,
            latency: 0.2,
        });
        assert_eq!(done.id(), 3);
        assert!(!done.is_failed());
        assert!(done.output().is_ok());
        let failed = GenResponse::Failed(GenFailure {
            id: 4,
            reason: FailReason::WorkerLost,
            retries: 2,
            latency: 0.3,
        });
        assert_eq!(failed.id(), 4);
        assert!(failed.is_failed());
        let err = failed.output().unwrap_err();
        assert_eq!(err.retries, 2);
        let api = err.to_api_error(0, 25);
        assert_eq!(api.status, 503);
        assert_eq!(api.code, "worker_lost");
    }

    #[test]
    fn job_ctx_and_stage() {
        let (tx, _rx) = sync_channel(1);
        let ctx = Arc::new(ReqCtx::new(11, 1, vec![], 4, None, 1, tx));
        let job = Job::Encode { ctx: Arc::clone(&ctx), shard: 0, patches: vec![], tiles: 1, stream: false };
        assert_eq!(job.ctx().id, 11);
        assert_eq!(job.stage(), Stage::Encode);
        let job2 = job.clone();
        assert_eq!(job2.ctx().id, 11);
        let pf = Job::Prefill { ctx: Arc::clone(&ctx), mm: Arc::new(vec![]) };
        assert_eq!(pf.stage(), Stage::Prefill);
        let kc = Job::KvChunk { ctx, group: 0, kv: vec![], len: 1, next_token: 2 };
        assert_eq!(kc.stage(), Stage::Decode);
    }
}
