//! Image → tile ("patch") → multimodal-token math for each model family.
//!
//! The paper's capacity and latency results hinge on how many tiles an
//! image of a given resolution produces (Table 3's `#Patch` column) and how
//! many LLM tokens those tiles become. Both families' published
//! preprocessing algorithms are implemented here and validated against the
//! paper's reported patch counts.

use super::spec::{LmmSpec, TilingPolicy};

/// Image resolution in pixels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Resolution {
    pub w: u32,
    pub h: u32,
}

impl Resolution {
    pub const fn new(w: u32, h: u32) -> Resolution {
        Resolution { w, h }
    }

    /// The three resolutions the paper evaluates (Tables 2–3).
    pub fn paper_set() -> [Resolution; 3] {
        [
            Resolution::new(313, 234),
            Resolution::new(787, 444),
            Resolution::new(4032, 3024),
        ]
    }

    /// The "4K" resolution used in most experiments.
    pub const fn four_k() -> Resolution {
        Resolution::new(4032, 3024)
    }

    pub fn pixels(&self) -> u64 {
        self.w as u64 * self.h as u64
    }

    pub fn aspect(&self) -> f64 {
        self.w as f64 / self.h as f64
    }
}

impl std::fmt::Display for Resolution {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}x{}", self.w, self.h)
    }
}

/// Number of tiles ("patches") the encoder processes for one image,
/// including any overview/thumbnail tile.
pub fn tiles_for_image(spec: &LmmSpec, res: Resolution) -> u32 {
    match spec.vision.tiling {
        TilingPolicy::MiniCpmSlice { scale_res, max_slices } => {
            minicpm_slices(res, scale_res, max_slices)
        }
        TilingPolicy::InternVlRatio { tile_px: _, max_tiles } => {
            internvl_tiles(res, max_tiles)
        }
        TilingPolicy::AudioClip => 1,
        TilingPolicy::Fixed { tiles } => tiles,
    }
}

/// LLM-facing multimodal tokens for one image.
pub fn mm_tokens_for_image(spec: &LmmSpec, res: Resolution) -> u64 {
    tiles_for_image(spec, res) as u64 * spec.vision.tokens_per_tile as u64
}

/// MiniCPM-V adaptive slicing: `multiple = ceil(W·H / scale_res²)` clamped
/// to `max_slices`; when the image is sliced, the model additionally
/// processes a downscaled overview image, hence `slices + 1`.
fn minicpm_slices(res: Resolution, scale_res: u32, max_slices: u32) -> u32 {
    let ideal = (res.pixels() as f64 / (scale_res as u64 * scale_res as u64) as f64).ceil() as u32;
    let multiple = ideal.clamp(1, max_slices);
    if multiple <= 1 {
        1
    } else {
        multiple + 1
    }
}

/// InternVL dynamic preprocessing: pick the tile grid `(i, j)` with
/// `i·j ≤ max_tiles` whose aspect ratio is closest to the image's (ties
/// broken toward the larger grid when the image has enough area), then add
/// a thumbnail tile when the grid has more than one tile.
fn internvl_tiles(res: Resolution, max_tiles: u32) -> u32 {
    let aspect = res.aspect();
    let area = res.pixels() as f64;
    let tile_px = 448.0_f64;
    // Candidate grids sorted by tile count ascending, exactly like the
    // published `find_closest_aspect_ratio`.
    let mut grids: Vec<(u32, u32)> = Vec::new();
    for i in 1..=max_tiles {
        for j in 1..=max_tiles {
            if i * j <= max_tiles {
                grids.push((i, j));
            }
        }
    }
    grids.sort_by_key(|&(i, j)| i * j);

    let mut best = (1u32, 1u32);
    let mut best_diff = f64::INFINITY;
    for &(i, j) in &grids {
        let target = i as f64 / j as f64;
        let diff = (aspect - target).abs();
        if diff < best_diff {
            best_diff = diff;
            best = (i, j);
        } else if diff == best_diff {
            // Tie-break from the reference implementation: only move to the
            // larger grid when the image has enough pixels to fill half of
            // that grid's canvas.
            if area > 0.5 * tile_px * tile_px * (i * j) as f64 {
                best = (i, j);
            }
        }
    }
    let n = best.0 * best.1;
    if n > 1 {
        n + 1
    } else {
        1
    }
}

/// Total multimodal tokens for a request with `images` images at `res`.
pub fn mm_tokens_for_request(spec: &LmmSpec, images: u32, res: Resolution) -> u64 {
    images as u64 * mm_tokens_for_image(spec, res)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    /// Table 3's `#Patch` column, MiniCPM-V 2.6 rows: 1 / 3 / 10.
    #[test]
    fn minicpm_patch_counts_match_table3() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        assert_eq!(tiles_for_image(&spec, Resolution::new(313, 234)), 1);
        assert_eq!(tiles_for_image(&spec, Resolution::new(787, 444)), 3);
        assert_eq!(tiles_for_image(&spec, Resolution::new(4032, 3024)), 10);
    }

    /// Table 3's `#Patch` column, InternVL rows: 13 / 3 / 13.
    #[test]
    fn internvl_patch_counts_match_table3() {
        for id in [ModelId::InternVl2_8b, ModelId::InternVl2_26b] {
            let spec = LmmSpec::get(id);
            assert_eq!(tiles_for_image(&spec, Resolution::new(313, 234)), 13, "{id:?}");
            assert_eq!(tiles_for_image(&spec, Resolution::new(787, 444)), 3, "{id:?}");
            assert_eq!(tiles_for_image(&spec, Resolution::new(4032, 3024)), 13, "{id:?}");
        }
    }

    #[test]
    fn token_counts() {
        let mini = LmmSpec::get(ModelId::MiniCpmV26);
        // 10 tiles × 64 tokens at 4K.
        assert_eq!(mm_tokens_for_image(&mini, Resolution::four_k()), 640);
        let ivl = LmmSpec::get(ModelId::InternVl2_8b);
        // 13 tiles × 256 tokens at 4K.
        assert_eq!(mm_tokens_for_image(&ivl, Resolution::four_k()), 3328);
    }

    #[test]
    fn square_small_image_single_tile_minicpm() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        assert_eq!(tiles_for_image(&spec, Resolution::new(448, 448)), 1);
        // Just over one tile's area → 2 slices + overview.
        assert_eq!(tiles_for_image(&spec, Resolution::new(640, 448)), 3);
    }

    #[test]
    fn internvl_square_image() {
        let spec = LmmSpec::get(ModelId::InternVl2_8b);
        // Square → best grid by aspect is i == j; area rule favours 3×3=9
        // (+1 thumbnail).
        let t = tiles_for_image(&spec, Resolution::new(1024, 1024));
        assert!(t == 10, "got {t}");
    }

    #[test]
    fn request_tokens_scale_linearly() {
        let spec = LmmSpec::get(ModelId::InternVl2_8b);
        let one = mm_tokens_for_request(&spec, 1, Resolution::four_k());
        let four = mm_tokens_for_request(&spec, 4, Resolution::four_k());
        assert_eq!(four, 4 * one);
    }

    #[test]
    fn tiny_is_fixed_single_tile() {
        let spec = LmmSpec::get(ModelId::TinyLmm);
        for res in Resolution::paper_set() {
            assert_eq!(tiles_for_image(&spec, res), 1);
        }
        assert_eq!(mm_tokens_for_image(&spec, Resolution::new(64, 64)), 16);
    }
}
