//! Byte-level tokenizer for the tiny-lmm served by the real engine.
//!
//! The tiny model's vocabulary is 512: ids 0–255 are raw bytes, 256–259 are
//! control tokens, 260–511 are reserved for multimodal placeholder ids.
//! This is deliberately trivial — the serving system under test cares about
//! token *counts and timing*, not linguistic quality — but it is a real,
//! invertible tokenizer so decoded output can be checked end to end.

/// Beginning-of-sequence token.
pub const BOS: u32 = 256;
/// End-of-sequence token.
pub const EOS: u32 = 257;
/// Placeholder marking where an image's MM tokens are spliced in.
pub const IMAGE_PLACEHOLDER: u32 = 258;
/// Padding token.
pub const PAD: u32 = 259;
/// Vocabulary size (matches `LlmSpec::vocab` for `TinyLmm`).
pub const VOCAB: u32 = 512;

/// Encode text to token ids (bytes + BOS).
pub fn encode(text: &str) -> Vec<u32> {
    let mut out = Vec::with_capacity(text.len() + 1);
    out.push(BOS);
    out.extend(text.bytes().map(|b| b as u32));
    out
}

/// Decode token ids back to text, skipping control tokens.
pub fn decode(tokens: &[u32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t < 256)
        .map(|&t| t as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Build a prompt token sequence with `n_images` image placeholders
/// preceding the text (the layout the tiny-lmm prefill graph expects).
pub fn encode_multimodal(text: &str, n_images: usize) -> Vec<u32> {
    let mut out = Vec::with_capacity(text.len() + n_images + 1);
    out.push(BOS);
    for _ in 0..n_images {
        out.push(IMAGE_PLACEHOLDER);
    }
    out.extend(text.bytes().map(|b| b as u32));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let toks = encode("hello, world");
        assert_eq!(toks[0], BOS);
        assert_eq!(decode(&toks), "hello, world");
    }

    #[test]
    fn roundtrip_utf8() {
        let s = "café ✓";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn multimodal_layout() {
        let toks = encode_multimodal("hi", 3);
        assert_eq!(toks[0], BOS);
        assert_eq!(&toks[1..4], &[IMAGE_PLACEHOLDER; 3]);
        assert_eq!(decode(&toks), "hi");
    }

    #[test]
    fn control_tokens_within_vocab() {
        assert!(PAD < VOCAB && EOS < VOCAB);
    }
}
