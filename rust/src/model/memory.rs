//! GPU capacity model — the analysis behind §4.3: Figure 2 (batch/image
//! growth when the LLM leaves the GPU), Table 2 (max images per request),
//! Table 3 (max E/P batch sizes) and Table 8 (max KV-cache fraction).
//!
//! A node hosts some subset of {encoder weights, LLM weights}; after
//! weights, a fraction of the free memory is reserved for the KV cache
//! (the paper uses 80% in Tables 2–3), and what remains is the working
//! space that encode / prefill activations must fit into. The per-tile
//! workspace coefficients live in [`MemCoeffs`](super::spec::MemCoeffs)
//! and are calibrated against the paper's measured rows.

use super::spec::{DeviceSpec, LmmSpec};
use super::vision::{mm_tokens_for_image, tiles_for_image, Resolution};

/// What a node hosts — determines its weight footprint and which phases'
/// workspace it must provide.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeKind {
    /// EPD encode node: encoder weights only, MM cache, no KV cache.
    EncodeOnly,
    /// EPD prefill (or decode) node: LLM weights + KV cache.
    LlmOnly,
    /// Aggregated / DistServe prefill node: encoder + LLM colocated.
    Colocated,
}

/// Why a capacity query returned zero.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CapacityLimit {
    /// Fits the returned amount (> 0).
    Ok,
    /// Does not fit even at the minimum size (paper's "OOM").
    Oom,
    /// Exceeds the model's context limit (paper's "OOCL").
    OutOfContext,
}

/// The capacity model for one (model, device) pair.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    pub spec: LmmSpec,
    pub device: DeviceSpec,
    /// Non-weight fixed overhead (CUDA context, allocator slack, runtime).
    pub fixed_overhead: u64,
}

impl MemoryModel {
    pub fn new(spec: LmmSpec, device: DeviceSpec) -> MemoryModel {
        MemoryModel { spec, device, fixed_overhead: 0 }
    }

    /// Weight bytes resident on a node of the given kind.
    pub fn weight_bytes(&self, node: NodeKind) -> u64 {
        match node {
            NodeKind::EncodeOnly => self.spec.encoder_weight_bytes(),
            NodeKind::LlmOnly => self.spec.llm_weight_bytes(),
            NodeKind::Colocated => self.spec.total_weight_bytes(),
        }
    }

    /// Free memory after weights and fixed overhead.
    pub fn free_after_weights(&self, node: NodeKind) -> u64 {
        self.device
            .mem_bytes
            .saturating_sub(self.weight_bytes(node) + self.fixed_overhead)
    }

    /// Workspace available for activations once `kv_frac` of the free
    /// memory is reserved for the KV cache. Encode-only nodes hold no KV
    /// cache, so the reservation does not apply (§4.3: "since KV cache is
    /// also not required at E workers, the memory saving can be even
    /// higher").
    pub fn workspace_bytes(&self, node: NodeKind, kv_frac: f64) -> u64 {
        let free = self.free_after_weights(node);
        match node {
            NodeKind::EncodeOnly => free,
            _ => ((1.0 - kv_frac) * free as f64) as u64,
        }
    }

    /// Encode-phase workspace for a request with `images` images at `res`.
    pub fn encode_request_bytes(&self, images: u32, res: Resolution) -> u64 {
        let tiles = tiles_for_image(&self.spec, res) as u64 * images as u64;
        self.spec.mem.encode_ws_per_request + tiles * self.spec.mem.encode_ws_per_tile
    }

    /// Prefill-phase workspace for a request with `images` images at `res`.
    pub fn prefill_request_bytes(&self, images: u32, res: Resolution) -> u64 {
        let tiles = tiles_for_image(&self.spec, res) as u64 * images as u64;
        tiles * self.spec.mem.prefill_ws_per_tile
    }

    /// Combined workspace on a node of `kind` for one request. Colocated
    /// nodes run encode then prefill sequentially on the same GPU and can
    /// reuse a `coloc_reuse` fraction of the smaller phase's buffers.
    pub fn request_bytes(&self, node: NodeKind, images: u32, res: Resolution) -> u64 {
        let e = self.encode_request_bytes(images, res);
        let p = self.prefill_request_bytes(images, res);
        match node {
            NodeKind::EncodeOnly => e,
            NodeKind::LlmOnly => p,
            NodeKind::Colocated => {
                let reuse = (self.spec.mem.coloc_reuse * e.min(p) as f64) as u64;
                e + p - reuse
            }
        }
    }

    /// Prompt tokens a request contributes to the LLM context: MM tokens
    /// plus the text prompt.
    pub fn request_context_tokens(&self, images: u32, res: Resolution, prompt_tokens: u32) -> u64 {
        mm_tokens_for_image(&self.spec, res) * images as u64 + prompt_tokens as u64
    }

    /// Table 2: maximum images in a single request (batch = 1) on a node of
    /// `kind`, with `kv_frac` of free memory reserved for KV cache.
    /// Returns the count and the limiting factor.
    pub fn max_images_per_request(
        &self,
        node: NodeKind,
        res: Resolution,
        kv_frac: f64,
        prompt_tokens: u32,
    ) -> (u32, CapacityLimit) {
        let ws = self.workspace_bytes(node, kv_frac);
        let mut n = 0u32;
        loop {
            let next = n + 1;
            if self.request_bytes(node, next, res) > ws {
                break;
            }
            // Context limit applies wherever the LLM runs; an encode-only
            // node defers it to the prefill node, but the *request* is
            // still infeasible, so enforce it uniformly.
            if self.request_context_tokens(next, res, prompt_tokens) > self.spec.llm.max_context as u64 {
                return (n, CapacityLimit::OutOfContext);
            }
            n = next;
            if n > 100_000 {
                break; // tiny models: effectively unbounded
            }
        }
        if n == 0 {
            (0, CapacityLimit::Oom)
        } else {
            (n, CapacityLimit::Ok)
        }
    }

    /// Table 3: maximum batch size (concurrent requests) on a node of
    /// `kind` for requests with `images` images at `res`.
    pub fn max_batch(
        &self,
        node: NodeKind,
        images: u32,
        res: Resolution,
        kv_frac: f64,
    ) -> (u32, CapacityLimit) {
        let ws = self.workspace_bytes(node, kv_frac);
        let per_req = self.request_bytes(node, images, res);
        if per_req == 0 {
            return (u32::MAX, CapacityLimit::Ok);
        }
        let n = (ws / per_req) as u32;
        if n == 0 {
            (0, CapacityLimit::Oom)
        } else {
            (n, CapacityLimit::Ok)
        }
    }

    /// Table 8: the maximum fraction of free memory that can be given to
    /// the KV cache on the prefill node while one request with `images`
    /// images still fits. Returns percent (0–100).
    pub fn max_kv_frac_pct(
        &self,
        node: NodeKind,
        images: u32,
        res: Resolution,
        prompt_tokens: u32,
    ) -> (u32, CapacityLimit) {
        if self.request_context_tokens(images, res, prompt_tokens)
            > self.spec.llm.max_context as u64
        {
            return (0, CapacityLimit::OutOfContext);
        }
        let free = self.free_after_weights(node) as f64;
        if free <= 0.0 {
            return (0, CapacityLimit::Oom);
        }
        let need = self.request_bytes(node, images, res) as f64;
        if need > free {
            return (0, CapacityLimit::Oom);
        }
        let pct = ((1.0 - need / free) * 100.0).floor() as u32;
        (pct, CapacityLimit::Ok)
    }

    /// KV-cache capacity in tokens given a reservation fraction.
    pub fn kv_capacity_tokens(&self, node: NodeKind, kv_frac: f64) -> u64 {
        let bytes = (self.free_after_weights(node) as f64 * kv_frac) as u64;
        bytes / self.spec.llm.kv_bytes_per_token()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    fn model(id: ModelId) -> MemoryModel {
        MemoryModel::new(LmmSpec::get(id), DeviceSpec::a100())
    }

    /// Table 2, MiniCPM-V 2.6: DistServe {77, 26, 7} vs EPD {490, 165, 49}.
    /// The calibrated model must land within ~10% of each row.
    #[test]
    fn table2_minicpm_shape() {
        let m = model(ModelId::MiniCpmV26);
        let expect = [
            (Resolution::new(313, 234), 77u32, 490u32),
            (Resolution::new(787, 444), 26, 165),
            (Resolution::new(4032, 3024), 7, 49),
        ];
        for (res, dist, epd) in expect {
            let (d, _) = m.max_images_per_request(NodeKind::Colocated, res, 0.8, 22);
            let (e, _) = m.max_images_per_request(NodeKind::EncodeOnly, res, 0.8, 22);
            assert!(
                (d as f64 - dist as f64).abs() / dist as f64 <= 0.12,
                "{res}: dist {d} vs paper {dist}"
            );
            assert!(
                (e as f64 - epd as f64).abs() / epd as f64 <= 0.12,
                "{res}: epd {e} vs paper {epd}"
            );
            assert!(e > 5 * d, "EPD should dominate: {e} vs {d}");
        }
    }

    /// Table 2, InternVL2-8B: both systems stop at 19 images — the context
    /// limit, not memory (the paper calls this out explicitly).
    #[test]
    fn table2_internvl8b_context_limited() {
        let m = model(ModelId::InternVl2_8b);
        let res = Resolution::four_k();
        let (e, why) = m.max_images_per_request(NodeKind::EncodeOnly, res, 0.8, 22);
        assert_eq!(e, 19);
        assert_eq!(why, CapacityLimit::OutOfContext);
    }

    /// Table 3, MiniCPM-V 2.6 EPD E column: {49, 16, 4} at 10 images/req.
    #[test]
    fn table3_minicpm_encode_batches() {
        let m = model(ModelId::MiniCpmV26);
        let expect = [
            (Resolution::new(313, 234), 49u32),
            (Resolution::new(787, 444), 16),
            (Resolution::new(4032, 3024), 4),
        ];
        for (res, want) in expect {
            let (b, _) = m.max_batch(NodeKind::EncodeOnly, 10, res, 0.8);
            assert_eq!(b, want, "{res}");
        }
    }

    /// Table 3, InternVL2-26B DistServe column: {OOM, 1, OOM}.
    #[test]
    fn table3_internvl26_distserve_ooms() {
        let m = model(ModelId::InternVl2_26b);
        let (b1, l1) = m.max_batch(NodeKind::Colocated, 10, Resolution::new(313, 234), 0.8);
        assert_eq!((b1, l1), (0, CapacityLimit::Oom));
        let (b2, _) = m.max_batch(NodeKind::Colocated, 10, Resolution::new(787, 444), 0.8);
        assert_eq!(b2, 1);
        let (b3, l3) = m.max_batch(NodeKind::Colocated, 10, Resolution::four_k(), 0.8);
        assert_eq!((b3, l3), (0, CapacityLimit::Oom));
    }

    /// Table 8, MiniCPM rows: EPD {99, 97, 95, 92} at {5, 10, 20, 40}
    /// images, OOCL at 80; DistServe OOM from 40.
    #[test]
    fn table8_minicpm_kv_fracs() {
        let m = model(ModelId::MiniCpmV26);
        let res = Resolution::four_k();
        for (n, want) in [(5u32, 98u32), (10, 97), (20, 95), (40, 90)] {
            let (pct, ok) = m.max_kv_frac_pct(NodeKind::LlmOnly, n, res, 22);
            assert_eq!(ok, CapacityLimit::Ok);
            assert!((pct as i64 - want as i64).abs() <= 2, "{n} images: {pct} vs {want}");
        }
        let (_, why) = m.max_kv_frac_pct(NodeKind::LlmOnly, 80, res, 22);
        assert_eq!(why, CapacityLimit::OutOfContext);
        let (_, why) = m.max_kv_frac_pct(NodeKind::Colocated, 40, res, 22);
        assert_eq!(why, CapacityLimit::Oom);
        let (pct5, _) = m.max_kv_frac_pct(NodeKind::Colocated, 5, res, 22);
        assert!((pct5 as i64 - 86).abs() <= 2, "dist 5 images: {pct5}");
    }

    /// §4.3's headline: E workers see ~15× lower peak memory (93.3% saving)
    /// once neither LLM weights nor KV cache are resident.
    #[test]
    fn encode_node_memory_saving_15x() {
        let m = model(ModelId::MiniCpmV26);
        // Peak usage for a typical 2-image 4K request: weights + KV
        // reservation (colocated) vs encoder weights + encode workspace.
        let res = Resolution::four_k();
        let coloc = m.weight_bytes(NodeKind::Colocated) as f64
            + 0.8 * m.free_after_weights(NodeKind::Colocated) as f64
            + m.request_bytes(NodeKind::Colocated, 2, res) as f64;
        let enc = m.weight_bytes(NodeKind::EncodeOnly) as f64
            + m.encode_request_bytes(2, res) as f64;
        let ratio = coloc / enc;
        assert!(ratio > 12.0 && ratio < 20.0, "ratio {ratio}");
    }

    #[test]
    fn kv_capacity_tokens_positive_and_ordered() {
        let m = model(ModelId::InternVl2_8b);
        let llm_only = m.kv_capacity_tokens(NodeKind::LlmOnly, 0.8);
        let coloc = m.kv_capacity_tokens(NodeKind::Colocated, 0.8);
        assert!(llm_only > coloc);
        assert!(coloc > 100_000);
    }

    #[test]
    fn workspace_monotone_in_kv_frac() {
        let m = model(ModelId::MiniCpmV26);
        let w50 = m.workspace_bytes(NodeKind::Colocated, 0.5);
        let w80 = m.workspace_bytes(NodeKind::Colocated, 0.8);
        assert!(w50 > w80);
        // Encode node ignores kv_frac.
        assert_eq!(
            m.workspace_bytes(NodeKind::EncodeOnly, 0.5),
            m.workspace_bytes(NodeKind::EncodeOnly, 0.8)
        );
    }
}
