//! LMM model descriptions and the analytical GPU memory model.
//!
//! [`spec`] declares the three paper models (MiniCPM-V 2.6, InternVL2-8B,
//! InternVL2-26B), the audio model from Appendix A.1, and the runnable
//! `tiny-lmm` the real engine serves. [`vision`] implements each family's
//! image→tile→token math (MiniCPM adaptive slicing, InternVL closest-
//! aspect-ratio tiling). [`memory`] is the capacity model behind Figure 2
//! and Tables 2, 3 and 8.

pub mod spec;
pub mod vision;
pub mod memory;
pub mod tokenizer;

pub use memory::{MemoryModel, NodeKind, CapacityLimit};
pub use spec::{DeviceSpec, LlmSpec, LmmSpec, MemCoeffs, ModelId, TilingPolicy, VisionSpec};
pub use vision::{mm_tokens_for_image, tiles_for_image, Resolution};
