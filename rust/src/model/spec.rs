//! Model specifications for the LMMs used in the paper's evaluation
//! (Appendix E.2) plus the tiny runnable model served by the real engine.
//!
//! Parameter counts, hidden sizes and head geometry follow the public model
//! cards; where the paper's measured capacity tables imply an effective
//! value (e.g. the serving-time context limit), we use the implied value
//! and note it.

use crate::util::bytes::GIB;

/// Identifier for a supported model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    /// MiniCPM-V 2.6: SigLIP-400M encoder + Qwen2-7B LLM (§E.2).
    MiniCpmV26,
    /// InternVL2-8B: InternViT-300M-448px + internlm2.5-7b-chat.
    InternVl2_8b,
    /// InternVL2-26B: InternViT-6B-448px + internlm2-chat-20b.
    InternVl2_26b,
    /// ultravox-v0_3 (LLaMA3.1-8B + whisper-style audio encoder), App. A.1.
    UltravoxV03,
    /// The ~15M-parameter runnable model compiled to artifacts/ and served
    /// by the real engine.
    TinyLmm,
}

impl ModelId {
    pub fn all_paper_models() -> [ModelId; 3] {
        [ModelId::MiniCpmV26, ModelId::InternVl2_8b, ModelId::InternVl2_26b]
    }

    pub fn parse(s: &str) -> Option<ModelId> {
        match s {
            "minicpm-v-2.6" | "minicpm" => Some(ModelId::MiniCpmV26),
            "internvl2-8b" => Some(ModelId::InternVl2_8b),
            "internvl2-26b" => Some(ModelId::InternVl2_26b),
            "ultravox-v0.3" | "ultravox" => Some(ModelId::UltravoxV03),
            "tiny-lmm" | "tiny" => Some(ModelId::TinyLmm),
            _ => None,
        }
    }
}

/// How a vision encoder turns an image into tiles (the paper's "patches").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TilingPolicy {
    /// MiniCPM-V adaptive slicing: `ceil(W·H / scale_res²)` capped at
    /// `max_slices`, plus the downscaled overview image when sliced.
    MiniCpmSlice { scale_res: u32, max_slices: u32 },
    /// InternVL dynamic tiling: choose the grid (i, j) with i·j ≤ max_tiles
    /// whose aspect ratio is closest to the image's, plus a thumbnail tile
    /// when more than one tile is used.
    InternVlRatio { tile_px: u32, max_tiles: u32 },
    /// Audio: fixed number of encoder tokens per clip (duration-bucketed
    /// upstream), `tokens_per_tile` below is per clip.
    AudioClip,
    /// Fixed tile count per image (tiny-lmm: every image is one tile).
    Fixed { tiles: u32 },
}

/// Multimodal encoder description.
#[derive(Debug, Clone, PartialEq)]
pub struct VisionSpec {
    /// Encoder parameter count.
    pub params: u64,
    /// Encoder hidden size.
    pub hidden: u32,
    /// Encoder transformer depth.
    pub layers: u32,
    /// Raw ViT sequence length per tile (e.g. (448/14)² = 1024).
    pub raw_tokens_per_tile: u32,
    /// LLM-facing tokens emitted per tile after resampling/pixel-shuffle
    /// (MiniCPM: 64, InternVL: 256).
    pub tokens_per_tile: u32,
    pub tiling: TilingPolicy,
}

/// Language model description.
#[derive(Debug, Clone, PartialEq)]
pub struct LlmSpec {
    pub params: u64,
    pub hidden: u32,
    pub layers: u32,
    pub heads: u32,
    pub kv_heads: u32,
    pub head_dim: u32,
    /// Serving-time context limit (tokens). For the InternVL models this is
    /// the effective limit implied by the paper's Tables 2/8 (19 images ×
    /// 3328 tok fits for 8B; 20×3328 fits but 40×3328 OOCLs for 26B).
    pub max_context: u32,
    pub vocab: u32,
}

impl LlmSpec {
    /// KV-cache bytes per token at fp16: 2 (K and V) × layers × kv_heads ×
    /// head_dim × 2 bytes.
    pub fn kv_bytes_per_token(&self) -> u64 {
        2 * self.layers as u64 * self.kv_heads as u64 * self.head_dim as u64 * 2
    }
}

/// Empirical per-model memory coefficients, calibrated against the paper's
/// measured capacity tables (see DESIGN.md §Cost-model calibration and
/// EXPERIMENTS.md for the fit):
///
/// - `encode_ws_per_tile`: encoder-side workspace bytes per tile
///   (activations + preprocessed pixels + MM-cache slab share).
/// - `prefill_ws_per_tile`: prefill-side workspace bytes per tile
///   (projector output, eager-attention workspace, sampler buffers).
/// - `encode_ws_per_request`: fixed encoder workspace per request
///   (significant only for InternViT-6B).
/// - `coloc_reuse`: fraction of min(encode, prefill) workspace that an
///   aggregated (E+P on one GPU) worker can reuse between the sequential
///   phases. 0 = fully additive, 1 = max(e, p).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemCoeffs {
    pub encode_ws_per_tile: u64,
    pub prefill_ws_per_tile: u64,
    pub encode_ws_per_request: u64,
    pub coloc_reuse: f64,
}

/// Complete LMM spec.
#[derive(Debug, Clone, PartialEq)]
pub struct LmmSpec {
    pub id: ModelId,
    pub name: &'static str,
    pub vision: VisionSpec,
    pub llm: LlmSpec,
    pub mem: MemCoeffs,
}

const MB: u64 = 1_000_000; // decimal MB: calibration unit for workspace coefficients

impl LmmSpec {
    /// Look up the spec for a model.
    pub fn get(id: ModelId) -> LmmSpec {
        match id {
            ModelId::MiniCpmV26 => LmmSpec {
                id,
                name: "MiniCPM-V 2.6",
                vision: VisionSpec {
                    params: 400_000_000,
                    hidden: 1152,
                    layers: 27,
                    raw_tokens_per_tile: 1024,
                    tokens_per_tile: 64,
                    tiling: TilingPolicy::MiniCpmSlice { scale_res: 448, max_slices: 9 },
                },
                llm: LlmSpec {
                    params: 7_600_000_000,
                    hidden: 3584,
                    layers: 28,
                    heads: 28,
                    kv_heads: 4,
                    head_dim: 128,
                    max_context: 32_768,
                    vocab: 151_666,
                },
                mem: MemCoeffs {
                    encode_ws_per_tile: 172 * MB,
                    prefill_ws_per_tile: 16 * MB + 400_000,
                    encode_ws_per_request: 0,
                    coloc_reuse: 0.0,
                },
            },
            ModelId::InternVl2_8b => LmmSpec {
                id,
                name: "InternVL2-8B",
                vision: VisionSpec {
                    params: 300_000_000,
                    hidden: 1024,
                    layers: 24,
                    raw_tokens_per_tile: 1024,
                    tokens_per_tile: 256,
                    tiling: TilingPolicy::InternVlRatio { tile_px: 448, max_tiles: 12 },
                },
                llm: LlmSpec {
                    params: 7_700_000_000,
                    hidden: 4096,
                    layers: 32,
                    heads: 32,
                    kv_heads: 8,
                    head_dim: 128,
                    max_context: 65_536,
                    vocab: 92_553,
                },
                mem: MemCoeffs {
                    encode_ws_per_tile: 43 * MB,
                    prefill_ws_per_tile: 52 * MB,
                    encode_ws_per_request: 0,
                    coloc_reuse: 1.0,
                },
            },
            ModelId::InternVl2_26b => LmmSpec {
                id,
                name: "InternVL2-26B",
                vision: VisionSpec {
                    params: 5_600_000_000,
                    hidden: 3200,
                    layers: 45,
                    raw_tokens_per_tile: 1024,
                    tokens_per_tile: 256,
                    tiling: TilingPolicy::InternVlRatio { tile_px: 448, max_tiles: 12 },
                },
                llm: LlmSpec {
                    params: 20_200_000_000,
                    hidden: 5120,
                    layers: 48,
                    heads: 40,
                    kv_heads: 8,
                    head_dim: 128,
                    max_context: 131_072,
                    vocab: 92_553,
                },
                mem: MemCoeffs {
                    encode_ws_per_tile: 90 * MB + 500_000,
                    prefill_ws_per_tile: 65 * MB,
                    encode_ws_per_request: 673 * MB,
                    coloc_reuse: 0.0,
                },
            },
            ModelId::UltravoxV03 => LmmSpec {
                id,
                name: "ultravox-v0_3",
                vision: VisionSpec {
                    params: 640_000_000,
                    hidden: 1280,
                    layers: 32,
                    // Whisper-style encoder: each 30 s clip is a 1500-frame
                    // mel sequence processed at full length (~4800 effective
                    // positions incl. conv front-end); ~200 LLM tokens after
                    // the stack-and-project adapter. Calibrated so the
                    // Table 7 goodput ordering (EPD > vLLM > DistServe)
                    // reproduces.
                    raw_tokens_per_tile: 4800,
                    tokens_per_tile: 200,
                    tiling: TilingPolicy::AudioClip,
                },
                llm: LlmSpec {
                    params: 8_000_000_000,
                    hidden: 4096,
                    layers: 32,
                    heads: 32,
                    kv_heads: 8,
                    head_dim: 128,
                    max_context: 131_072,
                    vocab: 128_256,
                },
                mem: MemCoeffs {
                    encode_ws_per_tile: 60 * MB,
                    prefill_ws_per_tile: 20 * MB,
                    encode_ws_per_request: 0,
                    coloc_reuse: 0.0,
                },
            },
            ModelId::TinyLmm => LmmSpec {
                id,
                name: "tiny-lmm",
                vision: VisionSpec {
                    params: 1_600_000,
                    hidden: 128,
                    layers: 2,
                    raw_tokens_per_tile: 64,
                    tokens_per_tile: 16,
                    tiling: TilingPolicy::Fixed { tiles: 1 },
                },
                llm: LlmSpec {
                    params: 13_000_000,
                    hidden: 256,
                    layers: 4,
                    heads: 8,
                    kv_heads: 8,
                    head_dim: 32,
                    max_context: 512,
                    vocab: 512,
                },
                mem: MemCoeffs {
                    encode_ws_per_tile: 4 * MB,
                    prefill_ws_per_tile: 1 * MB,
                    encode_ws_per_request: 0,
                    coloc_reuse: 0.0,
                },
            },
        }
    }

    /// Encoder weight bytes at fp16.
    pub fn encoder_weight_bytes(&self) -> u64 {
        self.vision.params * 2
    }

    /// LLM weight bytes at fp16.
    pub fn llm_weight_bytes(&self) -> u64 {
        self.llm.params * 2
    }

    /// Full-model weight bytes at fp16.
    pub fn total_weight_bytes(&self) -> u64 {
        self.encoder_weight_bytes() + self.llm_weight_bytes()
    }

    /// Bytes of one multimodal (post-projection) token at fp16.
    pub fn mm_token_bytes(&self) -> u64 {
        self.llm.hidden as u64 * 2
    }
}

/// GPU / NPU device memory + compute description used by the memory and
/// cost models.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    pub name: &'static str,
    /// Total device memory in bytes.
    pub mem_bytes: u64,
    /// Peak dense fp16/bf16 throughput, FLOP/s.
    pub peak_flops: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// Intra-node interconnect bandwidth (NVLink / HCCS), bytes/s.
    pub link_bw: f64,
    /// Per-transfer latency floor, seconds.
    pub link_latency: f64,
    /// Achievable model-flops-utilization for encode / prefill phases.
    pub mfu_encode: f64,
    pub mfu_prefill: f64,
}

impl DeviceSpec {
    /// NVIDIA A100-80GB (the paper's GPU testbed, §E.1: "A100 (82GB)").
    pub fn a100() -> DeviceSpec {
        DeviceSpec {
            name: "A100-80GB",
            mem_bytes: 80 * GIB,
            peak_flops: 312e12,
            hbm_bw: 2.0e12,
            link_bw: 300e9,
            link_latency: 1.0e-3,
            mfu_encode: 0.45,
            mfu_prefill: 0.58,
        }
    }

    /// Huawei Ascend 910B3 (App. F: 64 GB HBM; encode MFU derated so the
    /// encode:prefill latency ratio comes out 10–20% above the GPU, the
    /// effect Appendix F.1 measures).
    pub fn npu_910b3() -> DeviceSpec {
        DeviceSpec {
            name: "Ascend-910B3",
            mem_bytes: 64 * GIB,
            peak_flops: 280e12,
            hbm_bw: 1.2e12,
            link_bw: 196e9,
            link_latency: 1.5e-3,
            mfu_encode: 0.33,
            mfu_prefill: 0.48,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bytes::to_gib;

    #[test]
    fn paper_weight_shares_match_section_4_3() {
        // §4.3: removing the LLM saves ~95% / 96.2% / 78.3% of weight bytes.
        let m = LmmSpec::get(ModelId::MiniCpmV26);
        let share = m.llm_weight_bytes() as f64 / m.total_weight_bytes() as f64;
        assert!((share - 0.95).abs() < 0.01, "minicpm share {share}");

        let v8 = LmmSpec::get(ModelId::InternVl2_8b);
        let share = v8.llm_weight_bytes() as f64 / v8.total_weight_bytes() as f64;
        assert!((share - 0.962).abs() < 0.005, "ivl8 share {share}");

        let v26 = LmmSpec::get(ModelId::InternVl2_26b);
        let share = v26.llm_weight_bytes() as f64 / v26.total_weight_bytes() as f64;
        assert!((share - 0.783).abs() < 0.01, "ivl26 share {share}");
    }

    #[test]
    fn kv_bytes_per_token() {
        // Qwen2-7B GQA: 2 × 28 layers × 4 kv-heads × 128 dim × 2 B = 57344.
        let m = LmmSpec::get(ModelId::MiniCpmV26);
        assert_eq!(m.llm.kv_bytes_per_token(), 57_344);
        // internlm2.5-7b: 2 × 32 × 8 × 128 × 2 = 131072.
        let v8 = LmmSpec::get(ModelId::InternVl2_8b);
        assert_eq!(v8.llm.kv_bytes_per_token(), 131_072);
    }

    #[test]
    fn model_sizes_are_sane() {
        for id in ModelId::all_paper_models() {
            let s = LmmSpec::get(id);
            let gib = to_gib(s.total_weight_bytes());
            assert!(gib > 10.0 && gib < 60.0, "{}: {gib} GiB", s.name);
        }
        let tiny = LmmSpec::get(ModelId::TinyLmm);
        assert!(to_gib(tiny.total_weight_bytes()) < 0.1);
    }

    #[test]
    fn parse_roundtrip() {
        assert_eq!(ModelId::parse("minicpm"), Some(ModelId::MiniCpmV26));
        assert_eq!(ModelId::parse("internvl2-26b"), Some(ModelId::InternVl2_26b));
        assert_eq!(ModelId::parse("nope"), None);
    }

    #[test]
    fn devices() {
        let a = DeviceSpec::a100();
        let n = DeviceSpec::npu_910b3();
        assert!(a.mem_bytes > n.mem_bytes);
        // NPU derating makes encode relatively slower than prefill vs GPU.
        let gpu_ratio = a.mfu_prefill / a.mfu_encode;
        let npu_ratio = n.mfu_prefill / n.mfu_encode;
        assert!(npu_ratio > gpu_ratio * 1.05 && npu_ratio < gpu_ratio * 1.3);
    }
}
