//! Appendix A.1: the audio-modality experiment (Table 7) — ultravox-v0_3
//! with 24 audio clips per request on 4 GPUs.

use crate::core::config::EpdConfig;
use crate::core::slo::SloTable;
use crate::core::topology::Topology;
use crate::metrics::goodput::find_goodput;
use crate::model::spec::{DeviceSpec, ModelId};
use crate::sim::engine::{SimConfig, Simulator};
use crate::util::bench::TableReport;
use crate::util::rng::Rng;
use crate::workload::audio::AudioWorkload;
use crate::workload::Workload;

use super::common::{att, run_cell, spec, SEED};

fn audio_systems() -> [(&'static str, EpdConfig); 3] {
    [
        // Paper: vLLM DP4, DistServe 3P1D, EPD 2E1P1D.
        ("vLLM DP4", EpdConfig::aggregated(4, 64)),
        ("DistServe 3P1D", EpdConfig::distserve(3, 1, 1, 128)),
        ("EPD 2E1P1D", EpdConfig::epd(Topology::new(2, 1, 1), 1, 1, 128)),
    ]
}

pub fn table7_audio() -> Vec<TableReport> {
    let sp = spec(ModelId::UltravoxV03);
    let slo = SloTable::audio();
    let w = AudioWorkload::default();
    let mut t = TableReport::new(
        "table7_audio",
        "Table 7 — online audio benchmarking (ultravox-v0_3, 24 clips/request, 4 GPUs)",
        &["rate (r/s)", "vLLM", "DistServe", "EPD"],
    );
    for rate in [0.10, 0.25, 0.50, 1.00, 1.10, 1.15] {
        let mut cells = vec![format!("{rate:.2}")];
        for (_, cfg) in &audio_systems() {
            let out = run_cell(&sp, DeviceSpec::a100(), cfg, &w, 100, rate);
            cells.push(att(out.slo_attainment(slo)));
        }
        t.row(cells);
    }
    // Goodput row.
    let mut goodputs = vec!["goodput (r/s)".to_string()];
    for (_, cfg) in &audio_systems() {
        let sim = SimConfig::new(sp.clone(), DeviceSpec::a100(), cfg.clone());
        let g = find_goodput(
            |rate| {
                let mut rng = Rng::new(SEED);
                let reqs = w.generate(&sp, 100, rate, &mut rng);
                Simulator::run(&sim, &reqs).slo_attainment(slo)
            },
            0.05,
            0.9,
            0.05,
        );
        goodputs.push(format!("{:.2}", g.goodput));
    }
    t.row(goodputs);
    t.note("paper: goodput 1.01 (vLLM) / 0.45 (DistServe) / 1.16 (EPD)");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 7's shape: EPD's goodput beats DistServe's by a wide margin
    /// and edges out vLLM.
    #[test]
    fn audio_goodput_ordering() {
        let sp = spec(ModelId::UltravoxV03);
        let slo = SloTable::audio();
        let w = AudioWorkload::default();
        let mut results = Vec::new();
        for (name, cfg) in &audio_systems() {
            let sim = SimConfig::new(sp.clone(), DeviceSpec::a100(), cfg.clone());
            let g = find_goodput(
                |rate| {
                    let mut rng = Rng::new(SEED);
                    let reqs = w.generate(&sp, 60, rate, &mut rng);
                    Simulator::run(&sim, &reqs).slo_attainment(slo)
                },
                0.05,
                0.9,
                0.08,
            );
            results.push((*name, g.goodput));
        }
        let (vllm, ds, epd) = (results[0].1, results[1].1, results[2].1);
        assert!(epd > ds, "EPD {epd} vs DistServe {ds}");
        assert!(epd >= vllm * 0.9, "EPD {epd} vs vLLM {vllm}");
    }
}
