//! Paper-artifact regeneration: one function per table/figure in the
//! evaluation section, each returning [`TableReport`]s with our measured
//! values next to the paper's published numbers. Driven both by
//! `epdserve repro <id>` and by the `benches/` targets (`cargo bench`).
//!
//! Absolute latencies come from the calibrated simulator (DESIGN.md
//! §Substitutions); capacity numbers come from the analytical memory
//! model. The *shape* — who wins, by what factor, where crossovers sit —
//! is the reproduction target.

pub mod common;
pub mod memory_tables;
pub mod slo_figures;
pub mod latency;
pub mod ablations;
pub mod offline;
pub mod npu;
pub mod audio;

use crate::util::bench::TableReport;

/// All experiment ids in paper order.
pub const ALL_IDS: &[&str] = &[
    "fig2", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11", "fig12",
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8",
];

/// Run one experiment (or `all`).
pub fn run(id: &str) -> anyhow::Result<Vec<TableReport>> {
    let out = match id {
        "fig2" => memory_tables::fig2_capacity(),
        "fig5" => slo_figures::fig5_slo_synthetic(),
        "fig6" => latency::fig6_ttft_dist(),
        "fig7" => slo_figures::fig7_nextqa(),
        "fig8" => slo_figures::fig8_videomme(),
        "fig9" => npu::fig9_npu_slo(),
        "fig10" => offline::fig10_offline_throughput(),
        "fig11" => slo_figures::fig11_slo_6_8_images(),
        "fig12" => npu::fig12_npu_breakdown(),
        "table1" => latency::table1_ttft_frames(),
        "table2" => memory_tables::table2_images_per_req(),
        "table3" => memory_tables::table3_batch_sizes(),
        "table4" => ablations::table4_irp(),
        "table5" => ablations::table5_optimizer(),
        "table6" => ablations::table6_role_switch(),
        "table7" => audio::table7_audio(),
        "table8" => memory_tables::table8_kvcache(),
        "all" => {
            let mut all = Vec::new();
            for id in ALL_IDS {
                all.extend(run(id)?);
            }
            return Ok(all);
        }
        other => anyhow::bail!("unknown experiment id '{other}' (try 'all')"),
    };
    Ok(out)
}

/// Shared entry point for the `benches/` wrapper binaries: run one
/// experiment under the bench harness, reporting the failing id instead
/// of a context-free unwrap when an experiment errors.
pub fn bench_main(id: &str) {
    crate::util::bench::table(|| match run(id) {
        Ok(tables) => tables,
        Err(e) => panic!("repro '{id}' failed: {e:#}"),
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_ids_error_with_the_id() {
        let err = run("fig99").unwrap_err().to_string();
        assert!(err.contains("fig99"), "error names the id: {err}");
    }

    #[test]
    fn all_ids_are_unique_and_in_paper_order() {
        let mut seen = std::collections::HashSet::new();
        for id in ALL_IDS {
            assert!(seen.insert(*id), "duplicate id {id}");
        }
        assert_eq!(ALL_IDS.len(), 17);
    }
}
