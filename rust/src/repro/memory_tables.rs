//! Capacity artifacts from the memory model: Figure 2, Table 2, Table 3,
//! Table 8.

use crate::model::memory::{CapacityLimit, MemoryModel, NodeKind};
use crate::model::spec::{DeviceSpec, LmmSpec, ModelId};
use crate::model::vision::Resolution;
use crate::util::bench::TableReport;

fn mm(id: ModelId) -> MemoryModel {
    MemoryModel::new(LmmSpec::get(id), DeviceSpec::a100())
}

fn cap_str(n: u32, why: CapacityLimit) -> String {
    match why {
        CapacityLimit::Oom if n == 0 => "OOM".to_string(),
        CapacityLimit::OutOfContext if n == 0 => "OOCL".to_string(),
        CapacityLimit::OutOfContext => format!("{n} (ctx)"),
        _ => n.to_string(),
    }
}

/// Figure 2: removing the LLM from the GPU grows max batch and images/req
/// (MiniCPM-V 2.6).
pub fn fig2_capacity() -> Vec<TableReport> {
    let m = mm(ModelId::MiniCpmV26);
    let mut t = TableReport::new(
        "fig2_capacity",
        "Fig 2 — supported batch & images/req with vs without LLM on the GPU (MiniCPM-V 2.6)",
        &["resolution", "metric", "with LLM (agg.)", "LLM removed (E-only)", "gain"],
    );
    for res in Resolution::paper_set() {
        let (b_with, _) = m.max_batch(NodeKind::Colocated, 1, res, 0.8);
        let (b_wo, _) = m.max_batch(NodeKind::EncodeOnly, 1, res, 0.8);
        t.row(vec![
            res.to_string(),
            "max batch (1 img/req)".into(),
            b_with.to_string(),
            b_wo.to_string(),
            format!("{:.1}x", b_wo as f64 / b_with.max(1) as f64),
        ]);
        let (i_with, w1) = m.max_images_per_request(NodeKind::Colocated, res, 0.8, 22);
        let (i_wo, w2) = m.max_images_per_request(NodeKind::EncodeOnly, res, 0.8, 22);
        t.row(vec![
            res.to_string(),
            "max images/request".into(),
            cap_str(i_with, w1),
            cap_str(i_wo, w2),
            format!("{:.1}x", i_wo as f64 / i_with.max(1) as f64),
        ]);
    }
    t.note("paper: removing the LLM enables much larger batches and image counts (Fig 2)");
    vec![t]
}

/// Table 2: max images per request, DistServe vs EPD, with paper values.
pub fn table2_images_per_req() -> Vec<TableReport> {
    let expect: &[(ModelId, &[(u32, u32, &str, &str)])] = &[
        (
            ModelId::MiniCpmV26,
            &[(313, 234, "77", "490"), (787, 444, "26", "165"), (4032, 3024, "7", "49")],
        ),
        (
            ModelId::InternVl2_8b,
            &[(313, 234, "19", "19"), (787, 444, "19", "19"), (4032, 3024, "19", "19")],
        ),
        (
            ModelId::InternVl2_26b,
            &[(313, 234, "1", "10"), (787, 444, "11", "45"), (4032, 3024, "1", "10")],
        ),
    ];
    let mut t = TableReport::new(
        "table2_images_per_req",
        "Table 2 — max images per request (batch 1, KV 80%)",
        &["model", "resolution", "DistServe", "EPD", "paper DistServe", "paper EPD"],
    );
    for (id, rows) in expect {
        let m = mm(*id);
        for (w, h, p_dist, p_epd) in *rows {
            let res = Resolution::new(*w, *h);
            let (d, wd) = m.max_images_per_request(NodeKind::Colocated, res, 0.8, 22);
            // EPD: the binding node is whichever of encode/prefill admits
            // fewer images.
            let (e1, we1) = m.max_images_per_request(NodeKind::EncodeOnly, res, 0.8, 22);
            let (e2, we2) = m.max_images_per_request(NodeKind::LlmOnly, res, 0.8, 22);
            let (e, we) = if e1 <= e2 { (e1, we1) } else { (e2, we2) };
            t.row(vec![
                m.spec.name.to_string(),
                res.to_string(),
                cap_str(d, wd),
                cap_str(e, we),
                p_dist.to_string(),
                p_epd.to_string(),
            ]);
        }
    }
    t.note("headline: 10x more images at 4K for InternVL2-8B-class; 7-10x for 26B");
    vec![t]
}

/// Table 3: max batch sizes for E and P stages.
pub fn table3_batch_sizes() -> Vec<TableReport> {
    let expect: &[(ModelId, &[(u32, u32, &str, &str, &str)])] = &[
        (
            ModelId::MiniCpmV26,
            &[
                (313, 234, "7", "49", "86"),
                (787, 444, "2", "16", "29"),
                (4032, 3024, "OOM", "4", "9"),
            ],
        ),
        (
            ModelId::InternVl2_8b,
            &[
                (313, 234, "2", "15", "2"),
                (787, 444, "9", "67", "10"),
                (4032, 3024, "2", "15", "2"),
            ],
        ),
        (
            ModelId::InternVl2_26b,
            &[
                (313, 234, "OOM", "6", "1"),
                (787, 444, "1", "22", "4"),
                (4032, 3024, "OOM", "6", "1"),
            ],
        ),
    ];
    let mut t = TableReport::new(
        "table3_batch_sizes",
        "Table 3 — max batch size for E and P stages (10 images/req, KV 80%)",
        &[
            "model", "resolution", "#patch", "DistServe (E,P)", "EPD E", "EPD P",
            "paper (E,P)", "paper E", "paper P",
        ],
    );
    for (id, rows) in expect {
        let m = mm(*id);
        for (w, h, p_d, p_e, p_p) in *rows {
            let res = Resolution::new(*w, *h);
            let patches = crate::model::vision::tiles_for_image(&m.spec, res);
            let (d, wd) = m.max_batch(NodeKind::Colocated, 10, res, 0.8);
            let (e, we) = m.max_batch(NodeKind::EncodeOnly, 10, res, 0.8);
            let (p, wp) = m.max_batch(NodeKind::LlmOnly, 10, res, 0.8);
            t.row(vec![
                m.spec.name.to_string(),
                res.to_string(),
                patches.to_string(),
                cap_str(d, wd),
                cap_str(e, we),
                cap_str(p, wp),
                p_d.to_string(),
                p_e.to_string(),
                p_p.to_string(),
            ]);
        }
    }
    t.note("headline: 22x encode batch for InternVL2-26B at 787x444; 14.5x prefill for MiniCPM");
    vec![t]
}

/// Table 8: max KV-cache fraction on the prefill node.
pub fn table8_kvcache() -> Vec<TableReport> {
    let expect: &[(ModelId, &[(u32, &str, &str)])] = &[
        (
            ModelId::MiniCpmV26,
            &[(5, "86%", "99%"), (10, "74%", "97%"), (20, "49%", "95%"), (40, "OOM", "92%"), (80, "OOM", "OOCL")],
        ),
        (ModelId::InternVl2_8b, &[(5, "94%", "95%"), (10, "89%", "91%"), (20, "OOCL", "OOCL")]),
        (
            ModelId::InternVl2_26b,
            &[(5, "67%", "89%"), (10, "36%", "80%"), (20, "OOM", "63%"), (40, "OOM", "OOCL")],
        ),
    ];
    let mut t = TableReport::new(
        "table8_kvcache",
        "Table 8 — max KV-cache size (% of free memory) on the prefill node, 4K images",
        &["model", "#images/req", "DistServe", "EPD", "paper DistServe", "paper EPD"],
    );
    let res = Resolution::four_k();
    for (id, rows) in expect {
        let m = mm(*id);
        for (n, p_d, p_e) in *rows {
            let (d, wd) = m.max_kv_frac_pct(NodeKind::Colocated, *n, res, 22);
            let (e, we) = m.max_kv_frac_pct(NodeKind::LlmOnly, *n, res, 22);
            let s = |v: u32, w: CapacityLimit| match w {
                CapacityLimit::Ok => format!("{v}%"),
                CapacityLimit::Oom => "OOM".to_string(),
                CapacityLimit::OutOfContext => "OOCL".to_string(),
            };
            t.row(vec![
                m.spec.name.to_string(),
                n.to_string(),
                s(d, wd),
                s(e, we),
                p_d.to_string(),
                p_e.to_string(),
            ]);
        }
    }
    t.note("headline: 2.2x larger KV for InternVL2-26B @10 images (80% vs 36%)");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_capacity_tables_build() {
        for t in fig2_capacity()
            .into_iter()
            .chain(table2_images_per_req())
            .chain(table3_batch_sizes())
            .chain(table8_kvcache())
        {
            assert!(!t.rows.is_empty(), "{} empty", t.id);
            let rendered = t.render();
            assert!(rendered.contains(&t.id));
        }
    }

    /// The Table 2 headline ratio (10x more images at 4K for IVL-26B).
    #[test]
    fn table2_headline_ratios_hold() {
        let m = mm(ModelId::InternVl2_26b);
        let res = Resolution::four_k();
        let (d, _) = m.max_images_per_request(NodeKind::Colocated, res, 0.8, 22);
        let (e, _) = m.max_images_per_request(NodeKind::LlmOnly, res, 0.8, 22);
        // Paper: 10 vs 1 (10x). Our colocated model admits 3, so the
        // measured ratio is >=3x; see EXPERIMENTS.md for the deviation note.
        assert!(e >= 3 * d.max(1), "EPD {e} vs DistServe {d}");
        assert_eq!(e, 10, "EPD side matches the paper exactly");
    }

    /// Table 8 headline: ~2.2x KV for IVL-26B at 10 images.
    #[test]
    fn table8_headline_ratio_holds() {
        let m = mm(ModelId::InternVl2_26b);
        let res = Resolution::four_k();
        let (d, _) = m.max_kv_frac_pct(NodeKind::Colocated, 10, res, 22);
        let (e, _) = m.max_kv_frac_pct(NodeKind::LlmOnly, 10, res, 22);
        let r = e as f64 / d.max(1) as f64;
        assert!(r > 1.7 && r < 3.0, "ratio {r}");
    }
}
