//! Shared setup for the paper experiments: the three compared systems on
//! the 8-GPU testbed, simulation helpers, and formatting.

use crate::core::config::EpdConfig;
use crate::core::slo::Slo;
use crate::core::topology::Topology;
use crate::model::spec::{DeviceSpec, LmmSpec, ModelId};
use crate::sim::engine::{SimConfig, Simulator};
use crate::sim::outcome::SimOutcome;
use crate::util::rng::Rng;
use crate::workload::Workload;

/// Experiment seed — every table regenerates bit-identically.
pub const SEED: u64 = 0xEBD_2025;

/// The three compared systems on 8 GPUs (§4: EPD uses the optimizer's
/// 5E2P1D default; DistServe is 7P1D; vLLM is 8-way DP).
pub fn system_configs() -> [(&'static str, EpdConfig); 3] {
    [
        ("EPD", EpdConfig::epd(Topology::new(5, 2, 1), 1, 1, 128)),
        ("DistServe", EpdConfig::distserve(7, 1, 1, 128)),
        ("vLLM", EpdConfig::aggregated(8, 64)),
    ]
}

/// Run one (system, workload, rate) cell.
pub fn run_cell(
    spec: &LmmSpec,
    device: DeviceSpec,
    epd: &EpdConfig,
    workload: &dyn Workload,
    n: usize,
    rate: f64,
) -> SimOutcome {
    let cfg = SimConfig::new(spec.clone(), device, epd.clone());
    let mut rng = Rng::new(SEED);
    let reqs = workload.generate(spec, n, rate, &mut rng);
    Simulator::run(&cfg, &reqs)
}

/// SLO attainment across the three systems at one rate.
pub fn attainment_row(
    spec: &LmmSpec,
    device: DeviceSpec,
    workload: &dyn Workload,
    n: usize,
    rate: f64,
    slo: Slo,
) -> [f64; 3] {
    let mut out = [0.0; 3];
    for (i, (_, cfg)) in system_configs().iter().enumerate() {
        out[i] = run_cell(spec, device, cfg, workload, n, rate, ).slo_attainment(slo);
    }
    out
}

pub fn spec(id: ModelId) -> LmmSpec {
    LmmSpec::get(id)
}

/// Format an attainment as 0.00–1.00.
pub fn att(x: f64) -> String {
    format!("{x:.2}")
}

/// Format seconds.
pub fn secs(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a ratio like "2.4x".
pub fn ratio(x: f64) -> String {
    format!("{x:.1}x")
}
