//! NPU adaptation (§4.5 / Appendix F): Figure 9 (SLO attainment on the
//! Ascend-910B3 profile) and Figure 12 (encode/prefill breakdown, GPU vs
//! NPU).

use crate::core::slo::SloTable;
use crate::core::topology::Topology;
use crate::core::config::EpdConfig;
use crate::model::spec::{DeviceSpec, ModelId};
use crate::model::vision::{mm_tokens_for_image, tiles_for_image, Resolution};
use crate::sim::cost::CostModel;
use crate::util::bench::TableReport;
use crate::workload::synthetic::SyntheticWorkload;

use super::common::{att, run_cell, spec};

/// Figure 9: InternVL2-8B, eight 4K images per request, on the NPU
/// profile. EPD uses the optimizer's 5E2P1D.
pub fn fig9_npu_slo() -> Vec<TableReport> {
    let sp = spec(ModelId::InternVl2_8b);
    let slo = SloTable::npu();
    let w = SyntheticWorkload::new(8, 10);
    let device = DeviceSpec::npu_910b3();
    let systems = [
        ("EPD 5E2P1D", EpdConfig::epd(Topology::new(5, 2, 1), 1, 1, 128)),
        ("DistServe 7P1D", EpdConfig::distserve(7, 1, 1, 128)),
        ("vLLM DP8", EpdConfig::aggregated(8, 64)),
    ];
    let mut t = TableReport::new(
        "fig9_npu_slo",
        "Fig 9 — SLO attainment on NPUs (InternVL2-8B, 8x 4K images, TTFT<=8.5 TPOT<=0.12)",
        &["rate (r/s)", "EPD", "DistServe", "vLLM"],
    );
    for rate in [0.01, 0.02, 0.04, 0.08, 0.12, 0.2] {
        let mut cells = vec![format!("{rate:.2}")];
        for (_, cfg) in &systems {
            let out = run_cell(&sp, device, cfg, &w, 100, rate);
            cells.push(att(out.slo_attainment(slo)));
        }
        t.row(cells);
    }
    t.note("paper: EPD is the only system with positive SLO attainment under this workload");
    vec![t]
}

/// Figure 12: encode vs prefill latency breakdown across image counts on
/// GPU (a) and NPU (b), InternVL2-8B.
pub fn fig12_npu_breakdown() -> Vec<TableReport> {
    let sp = spec(ModelId::InternVl2_8b);
    let res = Resolution::four_k();
    let mut t = TableReport::new(
        "fig12_npu_breakdown",
        "Fig 12 — encode/prefill latency breakdown, GPU vs NPU (InternVL2-8B)",
        &["device", "#img", "encode (s)", "prefill (s)", "enc:pf ratio"],
    );
    let mut ratios = Vec::new();
    for (name, device) in [("A100 (GPU)", DeviceSpec::a100()), ("910B3 (NPU)", DeviceSpec::npu_910b3())] {
        let cm = CostModel::new(sp.clone(), device);
        for images in [1u32, 2, 4, 8] {
            let tiles = tiles_for_image(&sp, res) * images;
            let tokens = mm_tokens_for_image(&sp, res) * images as u64 + 22;
            let enc = cm.encode_time(tiles);
            let pf = cm.prefill_time(tokens);
            if images == 4 {
                ratios.push(enc / pf);
            }
            t.row(vec![
                name.to_string(),
                images.to_string(),
                format!("{enc:.3}"),
                format!("{pf:.3}"),
                format!("{:.3}", enc / pf),
            ]);
        }
    }
    t.note(format!(
        "NPU encode:prefill ratio is {:.0}% above GPU (paper: 10-20%)",
        100.0 * (ratios[1] / ratios[0] - 1.0)
    ));
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Figure 9's core claim: EPD attains the SLO at low rates on the NPU
    /// while both baselines stay near zero.
    #[test]
    fn fig9_only_epd_attains() {
        let sp = spec(ModelId::InternVl2_8b);
        let slo = SloTable::npu();
        let w = SyntheticWorkload::new(8, 10);
        let device = DeviceSpec::npu_910b3();
        let epd = run_cell(&sp, device, &EpdConfig::epd(Topology::new(5, 2, 1), 1, 1, 128), &w, 60, 0.02);
        let ds = run_cell(&sp, device, &EpdConfig::distserve(7, 1, 1, 128), &w, 60, 0.02);
        let vllm = run_cell(&sp, device, &EpdConfig::aggregated(8, 64), &w, 60, 0.02);
        let (a_epd, a_ds, a_v) = (
            epd.slo_attainment(slo),
            ds.slo_attainment(slo),
            vllm.slo_attainment(slo),
        );
        assert!(a_epd >= 0.9, "EPD att {a_epd}");
        assert!(a_ds < 0.5 && a_v < 0.5, "baselines {a_ds}/{a_v}");
    }

    /// Appendix F.1: NPU encode:prefill ratio 10–20% above GPU.
    #[test]
    fn fig12_ratio_shift() {
        let sp = spec(ModelId::InternVl2_8b);
        let res = Resolution::four_k();
        let tiles = tiles_for_image(&sp, res) * 4;
        let tokens = mm_tokens_for_image(&sp, res) * 4 + 22;
        let g = CostModel::new(sp.clone(), DeviceSpec::a100());
        let n = CostModel::new(sp.clone(), DeviceSpec::npu_910b3());
        let rg = g.encode_time(tiles) / g.prefill_time(tokens);
        let rn = n.encode_time(tiles) / n.prefill_time(tokens);
        let shift = rn / rg;
        assert!(shift > 1.08 && shift < 1.3, "shift {shift}");
    }
}
