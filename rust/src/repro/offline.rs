//! Figure 10 (Appendix A.3): offline throughput — encoder-count sweep,
//! images-per-request sweep, and batch-size sensitivity.

use crate::core::config::EpdConfig;
use crate::core::topology::Topology;
use crate::model::spec::{DeviceSpec, ModelId};
use crate::sim::engine::{SimConfig, Simulator};
use crate::util::bench::TableReport;
use crate::util::rng::Rng;
use crate::workload::synthetic::SyntheticWorkload;
use crate::workload::Workload;

use super::common::{spec, SEED};

/// Offline run: all requests submitted at t = 0 (rate = ∞).
fn offline_throughput(epd: &EpdConfig, images: u32, n: usize) -> f64 {
    let sp = spec(ModelId::MiniCpmV26);
    let cfg = SimConfig::new(sp.clone(), DeviceSpec::a100(), epd.clone());
    let mut w = SyntheticWorkload::new(images, 10);
    w.prompt_tokens = 7; // "What is the content of this image?"
    w.resolution = crate::model::vision::Resolution::new(313, 234); // single modest image
    let mut rng = Rng::new(SEED);
    let reqs = w.generate(&sp, n, f64::INFINITY, &mut rng);
    Simulator::run(&cfg, &reqs).throughput()
}

pub fn fig10_offline_throughput() -> Vec<TableReport> {
    let n = 1000;

    // Left: xE yP sweep with x + y = 7, 1 decode instance, vs DistServe 7P.
    let mut left = TableReport::new(
        "fig10_left_encoder_sweep",
        "Fig 10 (left) — offline throughput vs encoder/prefill split (1000 req, 1 image)",
        &["config", "throughput (req/s)"],
    );
    for e in 1..=6u32 {
        let p = 7 - e;
        let epd = EpdConfig::epd(Topology::new(e, p, 1), 8, 8, 128);
        left.row(vec![
            format!("{e}E{p}P1D"),
            format!("{:.2}", offline_throughput(&epd, 1, n)),
        ]);
    }
    let ds = EpdConfig::distserve(7, 1, 1, 128);
    left.row(vec![
        "DistServe 7P1D".into(),
        format!("{:.2}", offline_throughput(&ds, 1, n)),
    ]);
    left.note("paper: the optimizer's 5E2P pick maximizes E2E throughput");

    // Middle: images-per-request sweep, EPD 5E2P1D vs DistServe 7P1D.
    let mut mid = TableReport::new(
        "fig10_mid_images_sweep",
        "Fig 10 (middle) — offline throughput vs images/request",
        &["#images", "EPD 5E2P1D", "DistServe 7P1D"],
    );
    let epd = EpdConfig::epd(Topology::new(5, 2, 1), 8, 8, 128);
    for images in [1u32, 2, 4, 8] {
        mid.row(vec![
            images.to_string(),
            format!("{:.2}", offline_throughput(&epd, images, 400)),
            format!("{:.2}", offline_throughput(&ds, images, 400)),
        ]);
    }
    mid.note("paper: EPD's edge is largest at small image counts");

    // Right: encode/prefill batch-size sensitivity (batches set equal).
    let mut right = TableReport::new(
        "fig10_right_batch_sweep",
        "Fig 10 (right) — offline throughput vs encode=prefill batch size",
        &["batch", "EPD 5E2P1D throughput"],
    );
    for b in [1u32, 2, 4, 8, 16] {
        let cfg = EpdConfig::epd(Topology::new(5, 2, 1), b, b, 128);
        right.row(vec![
            b.to_string(),
            format!("{:.2}", offline_throughput(&cfg, 1, 400)),
        ]);
    }
    right.note("paper: EPD is relatively insensitive to E/P batch sizes");

    vec![left, mid, right]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The balanced 5E2P split must beat the most lopsided ones, and EPD
    /// must beat DistServe at 1 image (the paper's left/middle panels).
    #[test]
    fn fig10_shape() {
        let t_5e2p = offline_throughput(&EpdConfig::epd(Topology::new(5, 2, 1), 8, 8, 128), 1, 300);
        let t_1e6p = offline_throughput(&EpdConfig::epd(Topology::new(1, 6, 1), 8, 8, 128), 1, 300);
        let t_ds = offline_throughput(&EpdConfig::distserve(7, 1, 1, 128), 1, 300);
        assert!(t_5e2p > t_1e6p, "5E2P {t_5e2p} vs 1E6P {t_1e6p}");
        assert!(t_5e2p > t_ds, "5E2P {t_5e2p} vs DistServe {t_ds}");
    }

    /// Batch-size insensitivity (right panel): ≤ 30% spread across 1..16.
    #[test]
    fn fig10_batch_insensitive() {
        let mut vals = Vec::new();
        for b in [1u32, 4, 16] {
            let cfg = EpdConfig::epd(Topology::new(5, 2, 1), b, b, 128);
            vals.push(offline_throughput(&cfg, 1, 200));
        }
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = vals.iter().cloned().fold(0.0f64, f64::max);
        assert!(max / min < 1.6, "spread too large: {vals:?}");
    }
}
