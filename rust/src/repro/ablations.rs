//! Ablations: Table 4 (IRP), Table 5 (offline optimizer), Table 6 (dynamic
//! role switching).

use crate::core::config::EpdConfig;
use crate::core::slo::Slo;
use crate::core::topology::Topology;
use crate::model::spec::{DeviceSpec, ModelId};
use crate::optimizer::bayes::{BayesOpt, BayesOptConfig};
use crate::optimizer::objective::{ConfigEvaluator, Objective};
use crate::optimizer::space::SearchSpace;
use crate::sim::engine::{SimConfig, Simulator};
use crate::util::bench::TableReport;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::workload::synthetic::SyntheticWorkload;
use crate::workload::Workload;

use super::common::{ratio, run_cell, secs, spec, system_configs, SEED};

/// Table 4: disabling IRP degrades TTFT (MiniCPM, λ=0.25, 4K images).
pub fn table4_irp() -> Vec<TableReport> {
    let sp = spec(ModelId::MiniCpmV26);
    let mut t = TableReport::new(
        "table4_irp_ablation",
        "Table 4 — IRP ablation: mean TTFT (s) vs images/request",
        &["system", "2 img", "4 img", "6 img", "8 img"],
    );
    let epd_cfg = system_configs()[0].1.clone();
    let mut no_irp = epd_cfg.clone();
    no_irp.irp = false;

    let mut rows: Vec<Vec<f64>> = Vec::new();
    for cfg in [&epd_cfg, &no_irp] {
        let mut row = Vec::new();
        for images in [2u32, 4, 6, 8] {
            let w = SyntheticWorkload::new(images, 10);
            let out = run_cell(&sp, DeviceSpec::a100(), cfg, &w, 100, 0.25);
            row.push(out.mean_ttft());
        }
        rows.push(row);
    }
    t.row(
        std::iter::once("EPD".to_string())
            .chain(rows[0].iter().map(|x| secs(*x)))
            .collect(),
    );
    t.row(
        std::iter::once("w/o IRP".to_string())
            .chain(
                rows[1]
                    .iter()
                    .zip(&rows[0])
                    .map(|(wo, with)| format!("{} ({})", secs(*wo), ratio(wo / with))),
            )
            .collect(),
    );
    t.note("paper: 0.92/1.02/1.14/1.74 vs 1.46(1.6x)/2.47(2.4x)/3.37(2.9x)/4.27(2.5x)");
    vec![t]
}

/// Table 5: optimizer vs 10 random configurations (6 images, MiniCPM).
pub fn table5_optimizer() -> Vec<TableReport> {
    let sp = spec(ModelId::MiniCpmV26);
    let w = SyntheticWorkload::new(6, 10);
    let slo = Slo::new(3.90, 0.06);
    let ev = ConfigEvaluator {
        spec: sp.clone(),
        device: DeviceSpec::a100(),
        workload: &w,
        objective: Objective { beta: 0.0, gpu_cost: 1.0, slo, threshold: 0.9 },
        n_requests: 60,
        seed: SEED,
    };
    let space = SearchSpace::paper_default(8);
    let opt = BayesOpt::new(
        space.clone(),
        BayesOptConfig { init_samples: 6, budget: 14, candidates: 128, seed: 11 },
    );
    let bo = opt.run(|p| ev.goodput(p));
    let best_goodput = bo.best_value;
    let (best_ttft, best_tpot) = ev.latency_at_rate(&bo.best, best_goodput.max(0.05));

    // Random baseline: expected metric over 10 uniform samples (App. E.4),
    // evaluated at the SAME rate as the optimized system's goodput.
    let mut rng = Rng::new(77);
    let mut goodputs = Vec::new();
    let mut ttfts = Vec::new();
    let mut tpots = Vec::new();
    for _ in 0..10 {
        let p = space.sample(&mut rng);
        goodputs.push(ev.goodput(&p));
        let (a, b) = ev.latency_at_rate(&p, best_goodput.max(0.05));
        ttfts.push(a);
        tpots.push(b);
    }
    let rnd_goodput = stats::mean(&goodputs);
    let rnd_ttft = stats::mean(&ttfts);
    let rnd_tpot = stats::mean(&tpots);

    let mut t = TableReport::new(
        "table5_optimizer_ablation",
        "Table 5 — offline optimizer ablation (MiniCPM, 6 images/req)",
        &["system", "goodput (r/s)", "TTFT (s)", "TPOT (s)", "best config"],
    );
    t.row(vec![
        "EPD (optimized)".into(),
        format!("{best_goodput:.2}"),
        secs(best_ttft),
        format!("{best_tpot:.3}"),
        format!("{} E{}P{}D irp={}", bo.best.topology, bo.best.batch_e, bo.best.batch_p, bo.best.irp),
    ]);
    t.row(vec![
        "w/o Opt. (random x10)".into(),
        format!("{rnd_goodput:.2} ({})", ratio(best_goodput / rnd_goodput.max(1e-9))),
        format!("{} ({})", secs(rnd_ttft), ratio(rnd_ttft / best_ttft.max(1e-9))),
        format!("{rnd_tpot:.3}"),
        "-".into(),
    ]);
    t.note("paper: goodput 1.25 vs 0.56 (2.2x), TTFT 2.12 vs 4.48 (2.1x)");
    vec![t]
}

/// Table 6: role switching under a workload shift (first 10 requests
/// generate 50 tokens, the rest 500; rate 3 r/s; one 4K image each).
pub fn table6_role_switch() -> Vec<TableReport> {
    let sp = spec(ModelId::MiniCpmV26);
    let make_reqs = || {
        let w = SyntheticWorkload::new(1, 50);
        let mut rng = Rng::new(SEED);
        let mut reqs = w.generate(&sp, 100, 3.0, &mut rng);
        for (i, r) in reqs.iter_mut().enumerate() {
            r.output_tokens = if i < 10 { 50 } else { 500 };
        }
        reqs
    };
    // Initial configuration optimized offline for the 50-token regime:
    // 5E1P2D (the paper's setup). §E.1: latency-sensitive experiments run
    // with batching disabled (batch 1 in every stage) — which is exactly
    // why the decode stage saturates when outputs jump to 500 tokens.
    let base = EpdConfig::epd(Topology::new(5, 1, 2), 1, 1, 1);

    let run = |switching: bool| {
        let mut epd = base.clone();
        epd.role_switching = switching;
        let mut cfg = SimConfig::new(sp.clone(), DeviceSpec::a100(), epd);
        cfg.switch_policy.cooldown = 2.0;
        cfg.switch_policy.min_pressure = 0.5;
        Simulator::run(&cfg, &make_reqs())
    };
    let with = run(true);
    let without = run(false);

    let mut t = TableReport::new(
        "table6_role_switch",
        "Table 6 — dynamic role switching under a workload shift (50 -> 500 output tokens)",
        &["system", "latency (s)", "TTFT (s)", "TPOT (s)", "switches"],
    );
    t.row(vec![
        "EPD".into(),
        secs(with.mean_latency()),
        secs(with.mean_ttft()),
        format!("{:.3}", with.mean_tpot()),
        with.role_switches.to_string(),
    ]);
    t.row(vec![
        "w/o Switch".into(),
        format!("{} ({})", secs(without.mean_latency()), ratio(without.mean_latency() / with.mean_latency().max(1e-9))),
        secs(without.mean_ttft()),
        format!("{:.3} ({})", without.mean_tpot(), ratio(without.mean_tpot() / with.mean_tpot().max(1e-9))),
        "0".into(),
    ]);
    t.note("paper: latency 28.01 vs 61.10 (2.2x), TPOT 0.05 vs 0.12 (2.4x); 5E1P2D -> 2E1P5D");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 4 shape: removing IRP costs >= 1.5x TTFT at every image count
    /// and worsens as images grow.
    #[test]
    fn irp_ablation_shape() {
        let sp = spec(ModelId::MiniCpmV26);
        let epd_cfg = system_configs()[0].1.clone();
        let mut no_irp = epd_cfg.clone();
        no_irp.irp = false;
        let mut ratios = Vec::new();
        for images in [2u32, 8] {
            let w = SyntheticWorkload::new(images, 10);
            let with = run_cell(&sp, DeviceSpec::a100(), &epd_cfg, &w, 60, 0.25);
            let without = run_cell(&sp, DeviceSpec::a100(), &no_irp, &w, 60, 0.25);
            ratios.push(without.mean_ttft() / with.mean_ttft());
        }
        assert!(ratios[0] > 1.4, "2-image ratio {}", ratios[0]);
        assert!(ratios[1] > ratios[0], "degradation grows: {ratios:?}");
    }

    /// Table 6 shape: switching recovers >= 1.5x end-to-end latency and TPOT
    /// under the decode-heavy shift.
    #[test]
    fn role_switch_recovers_latency() {
        let tables = table6_role_switch();
        let t = &tables[0];
        // Row 0 = EPD, row 1 = w/o Switch; parse the latency cells.
        let with: f64 = t.rows[0][1].parse().unwrap();
        let without: f64 = t.rows[1][1].split(' ').next().unwrap().parse().unwrap();
        assert!(without > 1.5 * with, "with {with} without {without}");
        assert!(t.rows[0][4] != "0", "at least one switch happened");
    }
}
