//! First-token latency artifacts: Figure 6 (TTFT distributions) and
//! Table 1 (TTFT vs video length).

use crate::model::spec::{DeviceSpec, ModelId};
use crate::util::bench::TableReport;
use crate::workload::synthetic::SyntheticWorkload;
use crate::workload::videomme::VideoMmeWorkload;

use super::common::{run_cell, secs, spec, system_configs};

/// Figure 6: TTFT distribution vs images/request for the three models.
/// (vLLM equals DistServe here — decode excluded — and is omitted, as in
/// the paper.)
pub fn fig6_ttft_dist() -> Vec<TableReport> {
    let mut t = TableReport::new(
        "fig6_ttft_dist",
        "Fig 6 — TTFT distribution vs #images/request (4K, out=10)",
        &[
            "model", "#img", "system", "p25", "p50", "p75", "max", "mean",
            "reduction vs DistServe",
        ],
    );
    for model in ModelId::all_paper_models() {
        let sp = spec(model);
        let rate = if model == ModelId::MiniCpmV26 { 0.25 } else { 0.08 };
        for images in [2u32, 4, 6, 8] {
            let w = SyntheticWorkload::new(images, 10);
            let systems = system_configs();
            let epd = run_cell(&sp, DeviceSpec::a100(), &systems[0].1, &w, 100, rate);
            let ds = run_cell(&sp, DeviceSpec::a100(), &systems[1].1, &w, 100, rate);
            let e = epd.ttft_summary();
            let d = ds.ttft_summary();
            let red = 100.0 * (1.0 - e.mean / d.mean.max(1e-9));
            for (name, s, r) in [("EPD", &e, format!("{red:.1}%")), ("DistServe", &d, "-".into())] {
                t.row(vec![
                    sp.name.to_string(),
                    images.to_string(),
                    name.to_string(),
                    secs(s.p25),
                    secs(s.p50),
                    secs(s.p75),
                    secs(s.max),
                    secs(s.mean),
                    r,
                ]);
            }
        }
    }
    t.note("paper: TTFT reductions up to 71.9% (MiniCPM), 32.8% (IVL-8B), 44.9% (IVL-26B)");
    vec![t]
}

/// Table 1: mean TTFT vs #frames on Video-MME at 1 req/s.
pub fn table1_ttft_frames() -> Vec<TableReport> {
    let sp = spec(ModelId::MiniCpmV26);
    let mut t = TableReport::new(
        "table1_ttft_frames",
        "Table 1 — mean TTFT (s) vs video length at rate 1 r/s (Video-MME)",
        &["system", "8 frames", "16", "32", "64", "paper (8/16/32/64)"],
    );
    let paper = [
        ("vLLM", "0.42/0.82/1.59/3.11"),
        ("DistServe", "0.42/0.81/1.54/3.08"),
        ("EPD", "0.24/0.30/0.49/1.00"),
    ];
    let systems = system_configs();
    // Paper order: vLLM, DistServe, EPD.
    for (sys_idx, (name, paper_row)) in [(2usize, paper[0]), (1, paper[1]), (0, paper[2])] {
        let mut cells = vec![name.to_string()];
        for frames in [8u32, 16, 32, 64] {
            let w = VideoMmeWorkload::with_frames(frames);
            let out = run_cell(&sp, DeviceSpec::a100(), &systems[sys_idx].1, &w, 100, 1.0);
            cells.push(secs(out.mean_ttft()));
        }
        cells.push(paper_row.to_string());
        t.row(cells);
    }
    t.note("paper: EPD reduces TTFT up to 68.2% vs DistServe; gap widens with video length");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::DeviceSpec;

    /// Figure 6's core claim: EPD reduces mean TTFT vs DistServe for every
    /// model, most strongly for MiniCPM (>50%).
    #[test]
    fn fig6_reductions_match_paper_shape() {
        let systems = system_configs();
        for (model, rate, min_red) in [
            (ModelId::MiniCpmV26, 0.25, 0.50),
            (ModelId::InternVl2_8b, 0.08, 0.15),
            (ModelId::InternVl2_26b, 0.08, 0.25),
        ] {
            let sp = spec(model);
            let w = SyntheticWorkload::new(4, 10);
            let epd = run_cell(&sp, DeviceSpec::a100(), &systems[0].1, &w, 60, rate);
            let ds = run_cell(&sp, DeviceSpec::a100(), &systems[1].1, &w, 60, rate);
            let red = 1.0 - epd.mean_ttft() / ds.mean_ttft();
            assert!(
                red > min_red,
                "{model:?}: reduction {red:.2} (EPD {:.2} vs DS {:.2})",
                epd.mean_ttft(),
                ds.mean_ttft()
            );
        }
    }

    /// Table 1's shape: EPD TTFT grows far slower with frame count, and the
    /// advantage widens (42.9% at 8 frames → 67.5% at 64 in the paper).
    #[test]
    fn table1_gap_widens_with_frames() {
        let sp = spec(ModelId::MiniCpmV26);
        let systems = system_configs();
        let red_at = |frames: u32| {
            let w = VideoMmeWorkload::with_frames(frames);
            let epd = run_cell(&sp, DeviceSpec::a100(), &systems[0].1, &w, 60, 1.0);
            let ds = run_cell(&sp, DeviceSpec::a100(), &systems[1].1, &w, 60, 1.0);
            1.0 - epd.mean_ttft() / ds.mean_ttft()
        };
        let r8 = red_at(8);
        let r64 = red_at(64);
        // Paper: 42.9% at 8 frames and 67.5% at 64. Our substrate shows
        // >=50% at both ends; the widening itself is visible unloaded but
        // is partially masked by encoder utilization at the fixed 1 r/s
        // (see EXPERIMENTS.md §Deviations).
        assert!(r8 > 0.5, "8-frame reduction {r8:.2}");
        assert!(r64 > 0.5, "64-frame reduction {r64:.2}");
    }
}
