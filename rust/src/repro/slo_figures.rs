//! SLO-attainment-vs-rate curves: Figures 5, 7, 8 and 11.

use crate::core::slo::SloTable;
use crate::model::spec::{DeviceSpec, ModelId};
use crate::util::bench::TableReport;
use crate::workload::nextqa::NextQaWorkload;
use crate::workload::synthetic::SyntheticWorkload;
use crate::workload::videomme::VideoMmeWorkload;

use super::common::{att, attainment_row, spec};

/// Per-model rate grids (req/s). MiniCPM serves far faster than the
/// InternVL models (fewer image tokens), hence different x ranges — the
/// paper's figures do the same.
fn rate_grid(model: ModelId) -> Vec<f64> {
    match model {
        ModelId::MiniCpmV26 => vec![0.1, 0.25, 0.5, 0.75, 1.0, 1.25],
        _ => vec![0.02, 0.04, 0.08, 0.15, 0.25, 0.4],
    }
}

fn slo_sweep_table(
    id: &str,
    title: &str,
    models: &[ModelId],
    images_list: &[u32],
    n_requests: usize,
) -> TableReport {
    let mut t = TableReport::new(
        id,
        title,
        &["model", "#img", "rate (r/s)", "EPD", "DistServe", "vLLM", "SLO (ttft/tpot)"],
    );
    for &model in models {
        let sp = spec(model);
        for &images in images_list {
            let slo = SloTable::synthetic(model, images).expect("slo row");
            let w = SyntheticWorkload::new(images, 10);
            for &rate in &rate_grid(model) {
                let a = attainment_row(&sp, DeviceSpec::a100(), &w, n_requests, rate, slo);
                t.row(vec![
                    sp.name.to_string(),
                    images.to_string(),
                    format!("{rate:.2}"),
                    att(a[0]),
                    att(a[1]),
                    att(a[2]),
                    format!("{:.2}/{:.3}", slo.ttft, slo.tpot),
                ]);
            }
        }
    }
    t
}

/// Figure 5: synthetic workload, 3 models × {2, 4} images/request.
pub fn fig5_slo_synthetic() -> Vec<TableReport> {
    let mut t = slo_sweep_table(
        "fig5_slo_synthetic",
        "Fig 5 — SLO attainment vs request rate (synthetic, 4K images, out=10)",
        &ModelId::all_paper_models(),
        &[2, 4],
        100,
    );
    t.note("paper: EPD >= 0.90 at low rates; DistServe/vLLM often < 0.10 (interference)");
    vec![t]
}

/// Figure 11: the 6- and 8-image extension.
pub fn fig11_slo_6_8_images() -> Vec<TableReport> {
    let mut t = slo_sweep_table(
        "fig11_slo_6_8_images",
        "Fig 11 — SLO attainment vs rate at 6 and 8 images/request",
        &ModelId::all_paper_models(),
        &[6, 8],
        100,
    );
    t.note("paper: EPD declines with image count but still dominates all baselines");
    vec![t]
}

/// Figure 7: NextQA (MiniCPM-V 2.6, 8 frames, TTFT<=5.6, TPOT<=0.06).
pub fn fig7_nextqa() -> Vec<TableReport> {
    let sp = spec(ModelId::MiniCpmV26);
    let slo = SloTable::nextqa();
    let w = NextQaWorkload::default();
    let mut t = TableReport::new(
        "fig7_nextqa",
        "Fig 7 — SLO attainment vs rate on NextQA (MiniCPM-V 2.6)",
        &["rate (r/s)", "EPD", "DistServe", "vLLM"],
    );
    for rate in [2.0, 4.0, 8.0, 12.0, 16.0, 20.0] {
        let a = attainment_row(&sp, DeviceSpec::a100(), &w, 100, rate, slo);
        t.row(vec![format!("{rate:.2}"), att(a[0]), att(a[1]), att(a[2])]);
    }
    t.note("paper: EPD is the only framework reaching 0.90 at low rates");
    vec![t]
}

/// Figure 8: Video-MME (64 frames, TTFT<=3.1, TPOT<=0.025).
pub fn fig8_videomme() -> Vec<TableReport> {
    let sp = spec(ModelId::MiniCpmV26);
    let slo = SloTable::videomme();
    let w = VideoMmeWorkload::default();
    let mut t = TableReport::new(
        "fig8_videomme",
        "Fig 8 — SLO attainment vs rate on Video-MME (MiniCPM-V 2.6, 64 frames)",
        &["rate (r/s)", "EPD", "DistServe", "vLLM"],
    );
    for rate in [0.25, 0.5, 1.0, 1.5, 2.0, 3.0] {
        let a = attainment_row(&sp, DeviceSpec::a100(), &w, 100, rate, slo);
        t.row(vec![format!("{rate:.2}"), att(a[0]), att(a[1]), att(a[2])]);
    }
    t.note("paper: EPD outperforms across all rates on temporal workloads");
    vec![t]
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The Figure 5 shape: at the lowest probed rate EPD attains >= 0.9
    /// while DistServe does not, for every model at 2 images.
    #[test]
    fn fig5_epd_dominates_at_low_rate() {
        for model in ModelId::all_paper_models() {
            let sp = spec(model);
            let slo = SloTable::synthetic(model, 2).unwrap();
            let w = SyntheticWorkload::new(2, 10);
            let rate = rate_grid(model)[0];
            let a = attainment_row(&sp, DeviceSpec::a100(), &w, 60, rate, slo);
            assert!(a[0] >= 0.9, "{model:?}: EPD att {} at rate {rate}", a[0]);
            assert!(
                a[0] > a[1] && a[0] > a[2],
                "{model:?}: EPD {} vs DS {} vLLM {}",
                a[0],
                a[1],
                a[2]
            );
        }
    }

    /// Attainment must not increase with rate (sanity of the sweep).
    #[test]
    fn attainment_monotone_decreasing_roughly() {
        let sp = spec(ModelId::MiniCpmV26);
        let slo = SloTable::synthetic(ModelId::MiniCpmV26, 2).unwrap();
        let w = SyntheticWorkload::new(2, 10);
        let lo = attainment_row(&sp, DeviceSpec::a100(), &w, 60, 0.1, slo)[0];
        let hi = attainment_row(&sp, DeviceSpec::a100(), &w, 60, 3.0, slo)[0];
        assert!(lo >= hi, "lo {lo} hi {hi}");
    }
}
