//! Byte-size arithmetic and pretty-printing. All memory accounting in the
//! model/cache layers flows through these helpers so units stay explicit.

/// Bytes in a kibibyte/mebibyte/gibibyte.
pub const KIB: u64 = 1024;
pub const MIB: u64 = 1024 * KIB;
pub const GIB: u64 = 1024 * MIB;

/// Convert GiB (float) to bytes.
pub fn gib(x: f64) -> u64 {
    (x * GIB as f64) as u64
}

/// Convert MiB (float) to bytes.
pub fn mib(x: f64) -> u64 {
    (x * MIB as f64) as u64
}

/// Bytes as fractional GiB.
pub fn to_gib(bytes: u64) -> f64 {
    bytes as f64 / GIB as f64
}

/// Human-readable byte count ("1.50 GiB", "320.0 MiB", "42 B").
pub fn human(bytes: u64) -> String {
    if bytes >= GIB {
        format!("{:.2} GiB", bytes as f64 / GIB as f64)
    } else if bytes >= MIB {
        format!("{:.1} MiB", bytes as f64 / MIB as f64)
    } else if bytes >= KIB {
        format!("{:.1} KiB", bytes as f64 / KIB as f64)
    } else {
        format!("{bytes} B")
    }
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0);
    a.div_ceil(b)
}

/// Exact cumulative split of `total` units into `parts` contiguous spans:
/// part `i` covers `[total*i/parts, total*(i+1)/parts)`, so the sizes
/// always sum to `total` exactly (possibly with empty parts when
/// `total < parts`). This is THE split used on both streamed handoff
/// edges — sim PD layer groups, the engine's `Job::KvChunk` slicing, and
/// their property tests — so the streamed payload is provably the
/// monolithic payload re-chunked.
pub fn cumulative_split(total: u64, parts: u64) -> Vec<u64> {
    assert!(parts > 0);
    let mut out = Vec::with_capacity(parts as usize);
    let mut sent = 0u64;
    for i in 1..=parts {
        let cum = total * i / parts;
        out.push(cum - sent);
        sent = cum;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions() {
        assert_eq!(gib(1.0), GIB);
        assert_eq!(mib(2.0), 2 * MIB);
        assert!((to_gib(GIB * 3 / 2) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn human_format() {
        assert_eq!(human(42), "42 B");
        assert_eq!(human(2 * KIB), "2.0 KiB");
        assert_eq!(human(GIB + GIB / 2), "1.50 GiB");
    }

    #[test]
    fn ceil_div_cases() {
        assert_eq!(ceil_div(0, 16), 0);
        assert_eq!(ceil_div(1, 16), 1);
        assert_eq!(ceil_div(16, 16), 1);
        assert_eq!(ceil_div(17, 16), 2);
    }

    #[test]
    fn cumulative_split_sums_exactly() {
        for (total, parts) in [(0u64, 3u64), (7, 3), (8, 8), (26646, 8), (5, 12)] {
            let s = cumulative_split(total, parts);
            assert_eq!(s.len(), parts as usize);
            assert_eq!(s.iter().sum::<u64>(), total, "total={total} parts={parts}");
        }
        assert_eq!(cumulative_split(10, 3), vec![3, 3, 4]);
    }
}
