//! TOML-subset parser for configuration files.
//!
//! Supports the features our config format uses: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! bool / array values, `#` comments, and bare or quoted keys. No
//! multi-line strings, dates or inline tables — `cluster.toml` does not
//! need them, and rejecting them loudly beats mis-parsing.

use std::collections::BTreeMap;

/// A TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(x) => Some(*x),
            TomlValue::Int(x) => Some(*x as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parsed document: dotted section path → (key → value).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

impl TomlDoc {
    /// Look up `key` in `section` ("" = top level).
    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }
    pub fn get_i64(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key)?.as_i64()
    }
    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_f64()
    }
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }

    /// Section names that start with `prefix.` (for array-of-config idioms
    /// like `[instance.0]`, `[instance.1]`).
    pub fn sections_with_prefix(&self, prefix: &str) -> Vec<&str> {
        let pfx = format!("{prefix}.");
        self.sections
            .keys()
            .filter(|k| k.starts_with(&pfx))
            .map(|k| k.as_str())
            .collect()
    }

    /// Parse a document.
    pub fn parse(input: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        doc.sections.entry(String::new()).or_default();
        let mut current = String::new();
        for (lineno, raw) in input.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| TomlError::at(lineno, "unterminated section header"))?
                    .trim();
                if name.is_empty() {
                    return Err(TomlError::at(lineno, "empty section name"));
                }
                current = name.to_string();
                doc.sections.entry(current.clone()).or_default();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| TomlError::at(lineno, "expected 'key = value'"))?;
            let key = line[..eq].trim().trim_matches('"').to_string();
            if key.is_empty() {
                return Err(TomlError::at(lineno, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            doc.sections
                .entry(current.clone())
                .or_default()
                .insert(key, value);
        }
        Ok(doc)
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("toml parse error on line {line}: {msg}")]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl TomlError {
    fn at(line0: usize, msg: &str) -> TomlError {
        TomlError {
            line: line0 + 1,
            msg: msg.to_string(),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue, TomlError> {
    if text.is_empty() {
        return Err(TomlError::at(lineno, "missing value"));
    }
    if let Some(rest) = text.strip_prefix('"') {
        let end = rest
            .find('"')
            .ok_or_else(|| TomlError::at(lineno, "unterminated string"))?;
        if !rest[end + 1..].trim().is_empty() {
            return Err(TomlError::at(lineno, "trailing characters after string"));
        }
        return Ok(TomlValue::Str(rest[..end].to_string()));
    }
    if text.starts_with('[') {
        let inner = text
            .strip_prefix('[')
            .unwrap()
            .strip_suffix(']')
            .ok_or_else(|| TomlError::at(lineno, "unterminated array"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim(), lineno)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.replace('_', "").parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(TomlError::at(lineno, &format!("cannot parse value '{text}'")))
}

/// Split on commas not inside nested brackets or strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_basic_doc() {
        let doc = TomlDoc::parse(
            r#"
# cluster definition
name = "a100-pod"

[cluster]
num_gpus = 8
gpu_mem_gb = 82.0
nvlink = true

[stage.encode]
instances = 5
batch = [1, 2, 4]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("a100-pod"));
        assert_eq!(doc.get_i64("cluster", "num_gpus"), Some(8));
        assert_eq!(doc.get_f64("cluster", "gpu_mem_gb"), Some(82.0));
        assert_eq!(doc.get_bool("cluster", "nvlink"), Some(true));
        let arr = doc.get("stage.encode", "batch").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_i64(), Some(4));
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = TomlDoc::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("", "x"), Some(3.0));
    }

    #[test]
    fn comments_inside_strings_kept() {
        let doc = TomlDoc::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(doc.get_str("", "k"), Some("a#b"));
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("m = [[1,2],[3,4]]").unwrap();
        let outer = doc.get("", "m").unwrap().as_arr().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_arr().unwrap()[0].as_i64(), Some(3));
    }

    #[test]
    fn section_prefix_listing() {
        let doc = TomlDoc::parse("[instance.0]\nrole=\"encode\"\n[instance.1]\nrole=\"decode\"\n").unwrap();
        let secs = doc.sections_with_prefix("instance");
        assert_eq!(secs, vec!["instance.0", "instance.1"]);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("x = ").is_err());
        assert!(TomlDoc::parse("x = \"unterminated").is_err());
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.get_i64("", "big"), Some(1_000_000));
    }
}
