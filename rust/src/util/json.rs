//! Minimal JSON: a value model, a recursive-descent parser and a writer.
//!
//! Used for metrics dumps (`results/*.json`), for parsing request bodies on
//! the HTTP frontend, and for workload trace files. Covers the whole JSON
//! grammar except surrogate-pair escapes beyond the BMP (sufficient here).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        write_value(self, &mut out, Some(2), 0);
        out
    }

    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

fn write_value(v: &Json, out: &mut String, indent: Option<usize>, depth: usize) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_value(item, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push(']');
        }
        Json::Obj(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, depth + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, depth + 1);
            }
            newline_indent(out, indent, depth);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(x: f64, out: &mut String) {
    if !x.is_finite() {
        out.push_str("null"); // JSON has no Inf/NaN
    } else if x == x.trunc() && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        // Surrogate pair handling for BMP+ chars.
                        let c = if (0xD800..0xDC00).contains(&code) {
                            if self.bytes[self.pos..].starts_with(b"\\u") {
                                self.pos += 2;
                                let lo = self.hex4()?;
                                let combined =
                                    0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(combined)
                            } else {
                                None
                            }
                        } else {
                            char::from_u32(code)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid \\u escape"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-decode the UTF-8 sequence starting at pos-1.
                    let start = self.pos - 1;
                    let len = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("invalid utf-8")),
                    };
                    if start + len > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..start + len])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = start + len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut code = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            code = code * 16 + d;
        }
        Ok(code)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "0", "-1.5", "3.25e2", "\"hi\""] {
            let v = Json::parse(s).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn write_escapes() {
        let v = Json::str("a\"b\\c\nd");
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_written_without_fraction() {
        assert_eq!(Json::num(42.0).to_string(), "42");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn reject_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""café 😀 日本""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀 日本");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn pretty_is_reparsable() {
        let v = Json::obj(vec![
            ("x", Json::arr(vec![Json::num(1.0), Json::num(2.0)])),
            ("y", Json::obj(vec![("z", Json::Bool(true))])),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn deep_object_access() {
        let v = Json::parse(r#"{"model":{"name":"tiny","layers":4}}"#).unwrap();
        let layers = v.get("model").unwrap().get("layers").unwrap().as_u64().unwrap();
        assert_eq!(layers, 4);
    }
}
