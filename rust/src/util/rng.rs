//! Deterministic PRNG + the distributions the workload generators need.
//!
//! Xoshiro256++ seeded through SplitMix64 — the standard construction; fast,
//! reproducible across platforms, and more than adequate statistically for
//! workload generation and randomized property tests. `Date/now`-free: every
//! consumer passes an explicit seed so simulations replay bit-identically.

/// Xoshiro256++ pseudo-random number generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (e.g. one per simulated instance).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in `[0, n)` (Lemire's method, unbiased).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential variate with rate `lambda` (mean `1/lambda`).
    /// Inter-arrival times of a Poisson process — §4.1's arrival model.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    /// Knuth's method for small lambda; PTRS-style normal approx fallback.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // Normal approximation with continuity correction for large lambda.
        let x = self.normal(lambda, lambda.sqrt());
        if x < 0.0 {
            0
        } else {
            (x + 0.5) as u64
        }
    }

    /// Normal variate via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Log-normal variate (used for content-length-ish distributions).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Zipf-distributed rank in `[1, n]` with exponent `s` (rejection
    /// sampling; used for skewed request popularity).
    pub fn zipf(&mut self, n: u64, s: f64) -> u64 {
        assert!(n >= 1);
        if s <= 0.0 {
            return 1 + self.below(n);
        }
        // Rejection-inversion (Hörmann).
        let h = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                (1.0 + x).ln()
            } else {
                ((1.0 + x).powf(1.0 - s) - 1.0) / (1.0 - s)
            }
        };
        let h_inv = |x: f64| -> f64 {
            if (s - 1.0).abs() < 1e-12 {
                x.exp() - 1.0
            } else {
                ((1.0 - s) * x + 1.0).powf(1.0 / (1.0 - s)) - 1.0
            }
        };
        let hx0 = h(0.5) - 1.0;
        let hn = h(n as f64 + 0.5);
        loop {
            let u = hx0 + self.f64() * (hn - hx0);
            let x = h_inv(u);
            let k = (x + 0.5).floor().max(1.0).min(n as f64);
            if u >= h(k - 0.5) - (k).powf(-s) {
                return k as u64;
            }
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..10_000 {
            let x = r.below(7) as usize;
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exp_mean_matches_rate() {
        let mut r = Rng::new(11);
        let lambda = 4.0;
        let n = 200_000;
        let sum: f64 = (0..n).map(|_| r.exp(lambda)).sum();
        let mean = sum / n as f64;
        assert!((mean - 1.0 / lambda).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 3.0, 80.0] {
            let n = 50_000;
            let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(17);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(5.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.05);
        assert!((var - 4.0).abs() < 0.15);
    }

    #[test]
    fn zipf_rank1_most_popular() {
        let mut r = Rng::new(19);
        let mut counts = [0u64; 10];
        for _ in 0..50_000 {
            counts[(r.zipf(10, 1.2) - 1) as usize] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[4] > counts[9]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
