//! Fixed-size worker thread pool over `std::sync::mpsc` (tokio is
//! unavailable offline). Used by the real engine for per-instance workers
//! and by the optimizer for parallel simulator evaluations.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A pool of worker threads executing submitted closures.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `size` workers (must be ≥ 1).
    pub fn new(size: usize) -> ThreadPool {
        assert!(size > 0, "thread pool must have at least one worker");
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("epd-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
        }
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.execute(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rrx.iter() {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.expect("missing result")).collect()
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // Close the channel; workers exit when recv() errors.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A one-shot value produced by another thread.
pub struct Promise<T> {
    rx: Receiver<T>,
}

impl<T: Send + 'static> Promise<T> {
    /// Run `f` on the pool and return a promise for its result.
    pub fn spawn<F: FnOnce() -> T + Send + 'static>(pool: &ThreadPool, f: F) -> Promise<T> {
        let (tx, rx) = channel();
        pool.execute(move || {
            let _ = tx.send(f());
        });
        Promise { rx }
    }

    /// Block until the value is ready.
    pub fn wait(self) -> T {
        self.rx.recv().expect("promise producer dropped")
    }

    /// Non-blocking poll.
    pub fn try_take(&self) -> Option<T> {
        self.rx.try_recv().ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<u64>>(), |x| x * x);
        assert_eq!(out, (0..50).map(|x| x * x).collect::<Vec<u64>>());
    }

    #[test]
    fn promise_roundtrip() {
        let pool = ThreadPool::new(2);
        let p = Promise::spawn(&pool, || 40 + 2);
        assert_eq!(p.wait(), 42);
    }

    #[test]
    #[should_panic]
    fn zero_size_panics() {
        let _ = ThreadPool::new(0);
    }
}
