//! Mini-criterion: the bench harness behind every `benches/*.rs` target
//! (criterion is unavailable offline). Two modes:
//!
//! - [`BenchRunner::time`] — classic micro-benchmark: warmup, N timed
//!   samples, median/MAD outlier rejection, mean ± CI report.
//! - [`table`]/[`TableReport`] — "regenerate the paper artifact" mode: runs
//!   a closure that produces labelled rows (the table/figure series) and
//!   writes them to stdout and `results/<id>.{txt,json}`.

use std::fmt::Write as _;
use std::time::Instant;

use crate::util::json::Json;
use crate::util::stats;

/// Micro-benchmark runner.
pub struct BenchRunner {
    pub warmup_iters: u32,
    pub samples: u32,
    pub iters_per_sample: u32,
}

impl Default for BenchRunner {
    fn default() -> Self {
        BenchRunner {
            warmup_iters: 50,
            samples: 30,
            iters_per_sample: 20,
        }
    }
}

/// Result of one micro-benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Mean ns/iter after outlier rejection.
    pub mean_ns: f64,
    pub ci95_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub samples_kept: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12.0} ns/iter (±{:.0}, p50 {:.0}, p99 {:.0}, n={})",
            self.name, self.mean_ns, self.ci95_ns, self.p50_ns, self.p99_ns, self.samples_kept
        )
    }
}

impl BenchRunner {
    pub fn quick() -> BenchRunner {
        BenchRunner {
            warmup_iters: 5,
            samples: 10,
            iters_per_sample: 3,
        }
    }

    /// Time `f`, amortized over `iters_per_sample` calls per sample.
    pub fn time<F: FnMut()>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut per_iter_ns = Vec::with_capacity(self.samples as usize);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..self.iters_per_sample {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64;
            per_iter_ns.push(dt / self.iters_per_sample as f64);
        }
        // Outlier rejection: keep samples within 5 MADs of the median.
        let med = stats::percentile(&per_iter_ns, 50.0);
        let mut devs: Vec<f64> = per_iter_ns.iter().map(|x| (x - med).abs()).collect();
        devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = stats::percentile_sorted(&devs, 50.0).max(1e-9);
        let kept: Vec<f64> = per_iter_ns
            .iter()
            .copied()
            .filter(|x| (x - med).abs() <= 5.0 * mad)
            .collect();
        let kept = if kept.is_empty() { per_iter_ns.clone() } else { kept };
        BenchResult {
            name: name.to_string(),
            mean_ns: stats::mean(&kept),
            ci95_ns: stats::ci95_half_width(&kept),
            p50_ns: stats::percentile(&kept, 50.0),
            p99_ns: stats::percentile(&kept, 99.0),
            samples_kept: kept.len(),
        }
    }
}

/// A labelled table of rows — the unit in which paper artifacts are
/// regenerated. Columns are strings so rows can mix numbers and "OOM".
#[derive(Debug, Clone, Default)]
pub struct TableReport {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    pub notes: Vec<String>,
}

impl TableReport {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> TableReport {
        TableReport {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.columns.len(),
            "row width mismatch in table {}",
            self.id
        );
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.columns.iter().map(|c| c.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} — {} ==", self.id, self.title);
        let hdr: Vec<String> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
            .collect();
        let _ = writeln!(out, "| {} |", hdr.join(" | "));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let _ = writeln!(out, "|-{}-|", sep.join("-|-"));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            let _ = writeln!(out, "| {} |", cells.join(" | "));
        }
        for n in &self.notes {
            let _ = writeln!(out, "  note: {n}");
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("title", Json::str(self.title.clone())),
            (
                "columns",
                Json::arr(self.columns.iter().map(|c| Json::str(c.clone()))),
            ),
            (
                "rows",
                Json::arr(
                    self.rows
                        .iter()
                        .map(|r| Json::arr(r.iter().map(|c| Json::str(c.clone())))),
                ),
            ),
            (
                "notes",
                Json::arr(self.notes.iter().map(|n| Json::str(n.clone()))),
            ),
        ])
    }

    /// Print to stdout and persist under `results/`.
    pub fn emit(&self) {
        let text = self.render();
        println!("{text}");
        let _ = std::fs::create_dir_all("results");
        let _ = std::fs::write(format!("results/{}.txt", self.id), &text);
        let _ = std::fs::write(format!("results/{}.json", self.id), self.to_json().pretty());
    }
}

/// Machine-readable perf-gate summary, written as
/// `results/BENCH_<id>.json` alongside the table artifacts and consumed
/// by `scripts/bench_json.sh` / `make bench-json` — the perf-trajectory
/// record of what each gated bench requires vs. what it measured.
#[derive(Debug, Clone)]
pub struct GateReport {
    /// Artifact id (`BENCH_<id>.json`).
    pub id: String,
    /// Human-readable gate statement, e.g. "handoff reduction >= 30%".
    pub gate: String,
    /// The gate threshold the measurement must meet.
    pub baseline: f64,
    /// What the bench measured.
    pub measured: f64,
    /// Whether the gate held.
    pub pass: bool,
}

impl GateReport {
    /// A ">= threshold" gate: passes when `measured >= baseline`.
    pub fn at_least(id: &str, gate: &str, baseline: f64, measured: f64) -> GateReport {
        GateReport {
            id: id.to_string(),
            gate: gate.to_string(),
            baseline,
            measured,
            pass: measured >= baseline,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("id", Json::str(self.id.clone())),
            ("gate", Json::str(self.gate.clone())),
            ("baseline", Json::num(self.baseline)),
            ("measured", Json::num(self.measured)),
            ("pass", Json::Bool(self.pass)),
        ])
    }

    /// Print to stdout and persist under `results/BENCH_<id>.json`.
    pub fn emit(&self) {
        println!(
            "[gate] {}: {} (baseline {:.4}, measured {:.4}) -> {}",
            self.id,
            self.gate,
            self.baseline,
            self.measured,
            if self.pass { "PASS" } else { "FAIL" }
        );
        let _ = std::fs::create_dir_all("results");
        let _ = std::fs::write(
            format!("results/BENCH_{}.json", self.id),
            self.to_json().pretty(),
        );
    }
}

/// Entry point used by the table/figure benches: runs `f` and emits every
/// produced table. `cargo bench` passes `--bench`; ignore argv entirely.
pub fn table<F: FnOnce() -> Vec<TableReport>>(f: F) {
    let t0 = Instant::now();
    let tables = f();
    for t in &tables {
        t.emit();
    }
    eprintln!("[bench] {} table(s) in {:.2}s", tables.len(), t0.elapsed().as_secs_f64());
}

/// Format helper: f64 with fixed decimals, used across the benches.
pub fn fmt(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn micro_bench_positive_time() {
        let r = BenchRunner::quick().time("spin", || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.samples_kept > 0);
    }

    #[test]
    fn table_render_aligned() {
        let mut t = TableReport::new("t0", "demo", &["model", "value"]);
        t.row(vec!["MiniCPM-V 2.6".into(), "49".into()]);
        t.row(vec!["IVL2-8B".into(), "19".into()]);
        let s = t.render();
        assert!(s.contains("MiniCPM-V 2.6"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = TableReport::new("t1", "demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn json_roundtrip() {
        let mut t = TableReport::new("t2", "demo", &["a"]);
        t.row(vec!["1".into()]);
        let j = t.to_json();
        assert_eq!(j.get("id").unwrap().as_str(), Some("t2"));
    }

    #[test]
    fn gate_report_threshold_and_json() {
        let g = GateReport::at_least("x", "gain >= 30%", 0.30, 0.42);
        assert!(g.pass);
        let j = g.to_json();
        assert_eq!(j.get("pass").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("baseline").unwrap().as_f64(), Some(0.30));
        let g = GateReport::at_least("x", "gain >= 30%", 0.30, 0.12);
        assert!(!g.pass);
    }
}
