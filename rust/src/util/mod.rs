//! Zero-dependency substrates.
//!
//! The build environment has no access to crates.io beyond a small vendored
//! set (no tokio / clap / serde / criterion / proptest), so the pieces a
//! production serving system normally pulls in are implemented here from
//! scratch: a PRNG with the distributions the workload generators need, a
//! JSON writer/parser for metrics dumps and traces, a TOML-subset parser for
//! config files, a CLI argument parser, a thread pool, descriptive
//! statistics, a `log` backend, a mini-criterion bench harness and a small
//! property-based testing framework.

pub mod rng;
pub mod stats;
pub mod json;
pub mod toml;
pub mod argp;
pub mod threadpool;
pub mod logging;
pub mod bench;
pub mod quickcheck;
pub mod bytes;
