//! Small property-based testing framework (proptest is unavailable
//! offline). Provides generators over a seeded [`Rng`], a `forall` runner
//! that reports the failing seed, and greedy input shrinking for the
//! common shapes (integers, vectors).
//!
//! Used for the coordinator invariants: block-manager conservation,
//! scheduler fairness, router consistency, role-switch safety.

use crate::util::rng::Rng;

/// A generator of values of type `T` from randomness.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Configuration of the property runner.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 256,
            seed: 0xE9D_5E24E ^ 0x9E37_79B9_7F4A_7C15,
            max_shrink_steps: 500,
        }
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum CheckResult<T> {
    Passed { cases: usize },
    Failed { seed: u64, case: usize, input: T, message: String },
}

/// Run `prop` against `cases` random inputs; panics with the failing seed
/// and (possibly shrunk) input on failure.
pub fn forall<T, G, P>(gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    forall_cfg(Config::default(), gen, prop)
}

/// Like [`forall`] with explicit configuration.
pub fn forall_cfg<T, G, P>(cfg: Config, gen: G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    match check(&cfg, &gen, &prop) {
        CheckResult::Passed { .. } => {}
        CheckResult::Failed { seed, case, input, message } => {
            panic!(
                "property failed (seed={seed}, case={case}):\n  input: {input:?}\n  error: {message}"
            );
        }
    }
}

/// Non-panicking property check.
pub fn check<T, G, P>(cfg: &Config, gen: &G, prop: &P) -> CheckResult<T>
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen.generate(&mut rng);
        if let Err(message) = prop(&input) {
            return CheckResult::Failed {
                seed: cfg.seed,
                case,
                input,
                message,
            };
        }
    }
    CheckResult::Passed { cases: cfg.cases }
}

/// Shrink a failing `Vec<T>` input by greedily removing chunks while the
/// property still fails. Returns the smallest failing input found.
pub fn shrink_vec<T, P>(mut input: Vec<T>, prop: P, max_steps: usize) -> Vec<T>
where
    T: Clone,
    P: Fn(&Vec<T>) -> Result<(), String>,
{
    debug_assert!(prop(&input).is_err(), "shrink_vec needs a failing input");
    let mut steps = 0;
    let mut chunk = (input.len() / 2).max(1);
    while chunk >= 1 && steps < max_steps {
        let mut progressed = false;
        let mut start = 0;
        while start < input.len() && steps < max_steps {
            let end = (start + chunk).min(input.len());
            let mut candidate = input.clone();
            candidate.drain(start..end);
            steps += 1;
            if prop(&candidate).is_err() {
                input = candidate;
                progressed = true;
                // do not advance: same start now covers new elements
            } else {
                start += chunk;
            }
        }
        if !progressed {
            if chunk == 1 {
                break;
            }
            chunk /= 2;
        }
    }
    input
}

/// Shrink a failing integer toward zero by bisection.
pub fn shrink_u64<P>(mut input: u64, prop: P, max_steps: usize) -> u64
where
    P: Fn(u64) -> Result<(), String>,
{
    debug_assert!(prop(input).is_err());
    let mut lo = 0u64;
    let mut steps = 0;
    while lo < input && steps < max_steps {
        let mid = lo + (input - lo) / 2;
        steps += 1;
        if prop(mid).is_err() {
            input = mid;
        } else {
            lo = mid + 1;
        }
    }
    input
}

// -------- common generators --------

/// Uniform usize in `[lo, hi]`.
pub fn usize_in(lo: usize, hi: usize) -> impl Gen<usize> {
    move |rng: &mut Rng| rng.range(lo, hi)
}

/// Uniform f64 in `[lo, hi)`.
pub fn f64_in(lo: f64, hi: f64) -> impl Gen<f64> {
    move |rng: &mut Rng| rng.uniform(lo, hi)
}

/// Vector with length in `[0, max_len]` of elements from `inner`.
pub fn vec_of<T, G: Gen<T>>(inner: G, max_len: usize) -> impl Gen<Vec<T>> {
    move |rng: &mut Rng| {
        let len = rng.range(0, max_len);
        (0..len).map(|_| inner.generate(rng)).collect()
    }
}

/// Pair generator.
pub fn pair<A, B>(ga: impl Gen<A>, gb: impl Gen<B>) -> impl Gen<(A, B)> {
    move |rng: &mut Rng| (ga.generate(rng), gb.generate(rng))
}

/// One of the provided values.
pub fn one_of<T: Clone>(choices: Vec<T>) -> impl Gen<T> {
    move |rng: &mut Rng| rng.choose(&choices).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(usize_in(0, 100), |&x| {
            if x <= 100 {
                Ok(())
            } else {
                Err("out of range".into())
            }
        });
    }

    #[test]
    fn failing_property_detected() {
        let cfg = Config { cases: 500, ..Default::default() };
        let result = check(&cfg, &usize_in(0, 100), &|&x: &usize| {
            if x < 90 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
        assert!(matches!(result, CheckResult::Failed { .. }));
    }

    #[test]
    fn shrink_vec_finds_minimal() {
        // Property: fails iff the vector contains a 7.
        let prop = |v: &Vec<u64>| {
            if v.contains(&7) {
                Err("has 7".into())
            } else {
                Ok(())
            }
        };
        let failing = vec![1, 2, 7, 3, 4, 7, 5];
        let minimal = shrink_vec(failing, prop, 1000);
        assert_eq!(minimal, vec![7]);
    }

    #[test]
    fn shrink_u64_bisects() {
        // Fails for x >= 37; minimal failing input is 37.
        let prop = |x: u64| if x >= 37 { Err("ge 37".into()) } else { Ok(()) };
        assert_eq!(shrink_u64(1_000_000, prop, 200), 37);
    }

    #[test]
    fn generators_deterministic_per_seed() {
        let g = vec_of(usize_in(0, 9), 16);
        let mut r1 = Rng::new(99);
        let mut r2 = Rng::new(99);
        assert_eq!(g.generate(&mut r1), g.generate(&mut r2));
    }
}
