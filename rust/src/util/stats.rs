//! Descriptive statistics for latency series: percentiles, histograms,
//! means with confidence intervals. Used by [`crate::metrics`] and by the
//! bench harness.

/// Summary statistics over a sample of f64 values.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute a summary; returns a zeroed summary for an empty slice.
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                max: 0.0,
                p25: 0.0,
                p50: 0.0,
                p75: 0.0,
                p90: 0.0,
                p95: 0.0,
                p99: 0.0,
            };
        }
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = mean(xs);
        Summary {
            n: xs.len(),
            mean,
            std: std_dev(xs, mean),
            min: sorted[0],
            max: *sorted.last().unwrap(),
            p25: percentile_sorted(&sorted, 25.0),
            p50: percentile_sorted(&sorted, 50.0),
            p75: percentile_sorted(&sorted, 75.0),
            p90: percentile_sorted(&sorted, 90.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Arithmetic mean (0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation.
pub fn std_dev(xs: &[f64], mean: f64) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
    var.sqrt()
}

/// Percentile by linear interpolation on a *sorted* slice, `q` in [0,100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&sorted, q)
}

/// 95% confidence half-width of the mean (normal approximation).
pub fn ci95_half_width(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    1.96 * std_dev(xs, m) / (xs.len() as f64).sqrt()
}

/// Fixed-bucket histogram over `[lo, hi)` with `nbuckets` equal buckets plus
/// overflow/underflow counters.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub buckets: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbuckets: usize) -> Histogram {
        assert!(hi > lo && nbuckets > 0);
        Histogram {
            lo,
            hi,
            buckets: vec![0; nbuckets],
            underflow: 0,
            overflow: 0,
            count: 0,
            sum: 0.0,
        }
    }

    pub fn record(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.buckets.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.buckets[idx.min(n - 1)] += 1;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return 0.0;
        }
        let target = (q * self.count as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.lo;
        }
        let width = (self.hi - self.lo) / self.buckets.len() as f64;
        for (i, &b) in self.buckets.iter().enumerate() {
            acc += b;
            if acc >= target {
                return self.lo + width * (i as f64 + 1.0);
            }
        }
        self.hi
    }
}

/// Streaming quantile sketch with a fixed *relative* error bound — the
/// O(1)-memory summary behind the simulator's timeline-free fast path
/// (`SimConfig::record_timelines = false`).
///
/// The design is the DDSketch log-bucketed summary: a positive sample `x`
/// lands in bucket `k = ceil(ln x / ln γ)` with `γ = (1 + α) / (1 − α)`,
/// so bucket `k` covers `(γ^(k−1), γ^k]` and the bucket midpoint
/// `2γ^k / (γ + 1)` is within relative error `α` of every sample in it.
/// [`QuantileSketch::quantile`] therefore returns a value `x̃` with
/// `|x̃ − x_q| ≤ α · x_q` where `x_q` is the exact nearest-rank
/// `q`-quantile. Zero samples (e.g. the defined-zero TPOT of single-token
/// requests) are counted exactly in a dedicated bucket; mean/min/max/sum
/// are exact.
///
/// Memory is independent of the sample count: the bucket map holds at
/// most `ln(max/min) / ln γ + 2` entries — ≈ 1,400 buckets for latencies
/// spanning 1 µs to 10⁶ s at the default α = 1% — versus one `f64` per
/// request for the exact path.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantileSketch {
    alpha: f64,
    ln_gamma: f64,
    buckets: std::collections::BTreeMap<i32, u64>,
    zero: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    /// The default 1%-relative-error sketch.
    fn default() -> Self {
        QuantileSketch::new(0.01)
    }
}

impl QuantileSketch {
    /// A sketch with relative accuracy `alpha` in (0, 1).
    pub fn new(alpha: f64) -> QuantileSketch {
        assert!(alpha > 0.0 && alpha < 1.0, "alpha must be in (0, 1)");
        let gamma = (1.0 + alpha) / (1.0 - alpha);
        QuantileSketch {
            alpha,
            ln_gamma: gamma.ln(),
            buckets: std::collections::BTreeMap::new(),
            zero: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Record one sample. Non-positive samples count in the exact zero
    /// bucket (latencies are never negative; TPOT is defined 0 for
    /// single-token requests).
    #[inline]
    pub fn record(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample");
        self.count += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if x <= 0.0 {
            self.zero += 1;
            return;
        }
        let key = (x.ln() / self.ln_gamma).ceil() as i32;
        *self.buckets.entry(key).or_insert(0) += 1;
    }

    /// Approximate `q`-quantile (`q` in [0, 1]): within relative error
    /// `alpha` of the exact nearest-rank quantile. 0 for an empty sketch.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).max(1);
        let mut acc = self.zero;
        if acc >= rank {
            return 0.0;
        }
        let gamma = self.ln_gamma.exp();
        for (&k, &c) in &self.buckets {
            acc += c;
            if acc >= rank {
                return 2.0 * gamma.powi(k) / (gamma + 1.0);
            }
        }
        self.max
    }

    /// Exact mean (0 for an empty sketch).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// The configured relative accuracy bound α.
    pub fn relative_accuracy(&self) -> f64 {
        self.alpha
    }

    /// Occupied buckets — the sketch's actual memory footprint.
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Merge another sketch of the same accuracy (parallel shards).
    pub fn merge(&mut self, other: &QuantileSketch) {
        assert_eq!(
            self.alpha.to_bits(),
            other.alpha.to_bits(),
            "cannot merge sketches of different accuracy"
        );
        for (&k, &c) in &other.buckets {
            *self.buckets.entry(k).or_insert(0) += c;
        }
        self.zero += other.zero;
        self.count += other.count;
        self.sum += other.sum;
        if other.count > 0 {
            self.min = self.min.min(other.min);
            self.max = self.max.max(other.max);
        }
    }
}

/// Online mean/variance accumulator (Welford). Constant memory — used in the
/// engine's hot path where storing every sample would allocate.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Welford {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    pub fn record(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.p90 - 90.1).abs() < 1e-9);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_single_element() {
        assert_eq!(percentile(&[3.0], 99.0), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_quantile() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..100 {
            h.record(i as f64 / 10.0);
        }
        assert_eq!(h.count, 100);
        assert_eq!(h.underflow, 0);
        assert_eq!(h.overflow, 0);
        let med = h.quantile(0.5);
        assert!((med - 5.0).abs() <= 1.0, "median~5, got {med}");
    }

    #[test]
    fn histogram_overflow_underflow() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(-1.0);
        h.record(2.0);
        h.record(0.5);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.count, 3);
    }

    #[test]
    fn welford_matches_batch() {
        let xs: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.record(x);
        }
        let m = mean(&xs);
        assert!((w.mean() - m).abs() < 1e-9);
        assert!((w.std() - std_dev(&xs, m)).abs() < 1e-9);
    }

    #[test]
    fn sketch_quantiles_within_relative_error_bound() {
        // The documented guarantee: |q̃ − x_q| ≤ α·x_q against the exact
        // nearest-rank quantile, across a heavy-tailed sample.
        let mut rng = crate::util::rng::Rng::new(17);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.lognormal(-1.0, 1.5)).collect();
        let mut sorted = xs.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for alpha in [0.01, 0.05] {
            let mut sk = QuantileSketch::new(alpha);
            for &x in &xs {
                sk.record(x);
            }
            for q in [0.01, 0.25, 0.5, 0.9, 0.95, 0.99, 0.999] {
                let rank = ((q * xs.len() as f64).ceil() as usize).max(1);
                let exact = sorted[rank - 1];
                let approx = sk.quantile(q);
                assert!(
                    (approx - exact).abs() <= alpha * exact + 1e-12,
                    "alpha={alpha} q={q}: approx {approx} vs exact {exact}"
                );
            }
            assert!((sk.mean() - mean(&xs)).abs() < 1e-9, "mean is exact");
            assert_eq!(sk.count(), xs.len() as u64);
            assert_eq!(sk.min(), sorted[0]);
            assert_eq!(sk.max(), *sorted.last().unwrap());
        }
    }

    #[test]
    fn sketch_memory_is_bounded_by_dynamic_range_not_samples() {
        let mut sk = QuantileSketch::new(0.01);
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..200_000 {
            sk.record(rng.uniform(1e-6, 1e6).max(1e-6));
        }
        // ln(1e12)/ln(γ) ≈ 1,382 buckets at α = 1%.
        assert!(sk.bucket_count() <= 1_400, "buckets {}", sk.bucket_count());
    }

    #[test]
    fn sketch_zero_and_empty_edge_cases() {
        let empty = QuantileSketch::default();
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.min(), 0.0);
        let mut sk = QuantileSketch::default();
        sk.record(0.0);
        sk.record(0.0);
        sk.record(4.0);
        assert_eq!(sk.quantile(0.5), 0.0, "zeros are exact");
        let p99 = sk.quantile(0.99);
        assert!((p99 - 4.0).abs() <= 0.01 * 4.0 + 1e-12);
    }

    #[test]
    fn sketch_merge_matches_single_pass() {
        let mut rng = crate::util::rng::Rng::new(9);
        let xs: Vec<f64> = (0..5_000).map(|_| rng.lognormal(0.0, 1.0)).collect();
        let mut whole = QuantileSketch::default();
        let mut a = QuantileSketch::default();
        let mut b = QuantileSketch::default();
        for (i, &x) in xs.iter().enumerate() {
            whole.record(x);
            if i % 2 == 0 {
                a.record(x);
            } else {
                b.record(x);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.quantile(0.9), whole.quantile(0.9));
        assert!((a.sum() - whole.sum()).abs() < 1e-9);
    }

    #[test]
    fn ci_shrinks_with_n() {
        let a: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let b: Vec<f64> = (0..1000).map(|i| (i % 10) as f64).collect();
        assert!(ci95_half_width(&b) < ci95_half_width(&a));
    }
}
