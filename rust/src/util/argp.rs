//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Model: `prog <subcommand> [--flag] [--key value] [positional...]`.
//! Declarative enough for `--help` generation; typed accessors with
//! defaults; unknown flags are hard errors so typos don't silently fall
//! through to defaults.

use std::collections::BTreeMap;

/// Specification of a single option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// true → boolean flag (no value); false → takes one value.
    pub is_flag: bool,
    pub default: Option<&'static str>,
}

/// Specification of a subcommand.
#[derive(Debug, Clone)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional: Vec<(&'static str, &'static str)>, // (name, help)
}

/// Parsed arguments for one subcommand.
#[derive(Debug, Clone)]
pub struct Args {
    pub cmd: String,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("option --{name} has no value and no default"))
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("option --{name} is not an integer"))
    }

    pub fn usize(&self, name: &str) -> usize {
        self.u64(name) as usize
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.str(name)
            .parse()
            .unwrap_or_else(|_| panic!("option --{name} is not a number"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

/// A CLI with subcommands.
#[derive(Debug, Clone)]
pub struct Cli {
    pub prog: &'static str,
    pub about: &'static str,
    pub cmds: Vec<CmdSpec>,
}

/// Parse failure (message already formatted for the user).
#[derive(Debug, thiserror::Error)]
#[error("{0}")]
pub struct ArgError(pub String);

impl Cli {
    pub fn new(prog: &'static str, about: &'static str) -> Cli {
        Cli {
            prog,
            about,
            cmds: Vec::new(),
        }
    }

    pub fn cmd(mut self, spec: CmdSpec) -> Cli {
        self.cmds.push(spec);
        self
    }

    /// Render top-level or per-command help text.
    pub fn help(&self, cmd: Option<&str>) -> String {
        match cmd.and_then(|c| self.cmds.iter().find(|s| s.name == c)) {
            Some(spec) => {
                let mut out = format!("{} {} — {}\n\nOptions:\n", self.prog, spec.name, spec.about);
                for o in &spec.opts {
                    let kind = if o.is_flag { "" } else { " <value>" };
                    let def = o
                        .default
                        .map(|d| format!(" [default: {d}]"))
                        .unwrap_or_default();
                    out.push_str(&format!("  --{}{kind}\n      {}{def}\n", o.name, o.help));
                }
                for (p, h) in &spec.positional {
                    out.push_str(&format!("  <{p}>\n      {h}\n"));
                }
                out
            }
            None => {
                let mut out = format!("{} — {}\n\nCommands:\n", self.prog, self.about);
                for c in &self.cmds {
                    out.push_str(&format!("  {:<22} {}\n", c.name, c.about));
                }
                out.push_str("\nRun with '<command> --help' for command options.\n");
                out
            }
        }
    }

    /// Parse argv (excluding the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, ArgError> {
        let cmd_name = argv
            .first()
            .ok_or_else(|| ArgError(self.help(None)))?
            .clone();
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(ArgError(self.help(None)));
        }
        let spec = self
            .cmds
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| {
                ArgError(format!(
                    "unknown command '{cmd_name}'\n\n{}",
                    self.help(None)
                ))
            })?;

        let mut args = Args {
            cmd: cmd_name.clone(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        };
        for o in &spec.opts {
            if let Some(d) = o.default {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Err(ArgError(self.help(Some(&cmd_name))));
            }
            if let Some(name) = tok.strip_prefix("--") {
                // --key=value form
                let (name, inline_val) = match name.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name, None),
                };
                let opt = spec.opts.iter().find(|o| o.name == name).ok_or_else(|| {
                    ArgError(format!("unknown option '--{name}' for '{cmd_name}'"))
                })?;
                if opt.is_flag {
                    if inline_val.is_some() {
                        return Err(ArgError(format!("flag '--{name}' takes no value")));
                    }
                    args.flags.insert(name.to_string(), true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| ArgError(format!("option '--{name}' needs a value")))?
                        }
                    };
                    args.values.insert(name.to_string(), val);
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        if args.positional.len() > spec.positional.len() {
            return Err(ArgError(format!(
                "too many positional arguments for '{cmd_name}'"
            )));
        }
        Ok(args)
    }
}

/// Convenience builder for an option that takes a value.
pub fn opt(name: &'static str, default: Option<&'static str>, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        is_flag: false,
        default,
    }
}

/// Convenience builder for a boolean flag.
pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec {
        name,
        help,
        is_flag: true,
        default: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("epdserve", "test").cmd(CmdSpec {
            name: "simulate",
            about: "run the simulator",
            opts: vec![
                opt("rate", Some("1.0"), "request rate"),
                opt("model", None, "model name"),
                flag("verbose", "chatty output"),
            ],
            positional: vec![("config", "config path")],
        })
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&sv(&["simulate"])).unwrap();
        assert_eq!(a.f64("rate"), 1.0);
        assert!(!a.flag("verbose"));
        assert!(a.get("model").is_none());
    }

    #[test]
    fn values_flags_positionals() {
        let a = cli()
            .parse(&sv(&["simulate", "--rate", "2.5", "--verbose", "cfg.toml"]))
            .unwrap();
        assert_eq!(a.f64("rate"), 2.5);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["cfg.toml"]);
    }

    #[test]
    fn key_equals_value_form() {
        let a = cli().parse(&sv(&["simulate", "--rate=0.25"])).unwrap();
        assert_eq!(a.f64("rate"), 0.25);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cli().parse(&sv(&["simulate", "--nope", "1"])).is_err());
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(cli().parse(&sv(&["frobnicate"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(&sv(&["simulate", "--rate"])).is_err());
    }

    #[test]
    fn help_lists_commands() {
        let h = cli().help(None);
        assert!(h.contains("simulate"));
        let h2 = cli().help(Some("simulate"));
        assert!(h2.contains("--rate"));
        assert!(h2.contains("default: 1.0"));
    }
}
