//! Command-line interface for the `epdserve` binary.
//!
//! Commands:
//! - `serve`      — start the real engine + HTTP frontend.
//! - `generate`   — one-shot generation through an in-process engine.
//! - `simulate`   — run the cluster simulator for a workload/config.
//! - `optimize`   — run the resource-allocation optimizer (§3.2.3).
//! - `repro`      — regenerate a paper table/figure (or `all`).
//! - `capacity`   — query the memory model (Tables 2/3/8 primitives).

use std::sync::Arc;

use crate::api::SubmitRequest;
use crate::core::config::{EpdConfig, RouterPolicy};
use crate::core::request::Priority;
use crate::core::slo::Slo;
use crate::core::topology::{DeploymentMode, Topology};
use crate::metrics::goodput::find_goodput;
use crate::model::memory::{MemoryModel, NodeKind};
use crate::model::spec::{DeviceSpec, LmmSpec, ModelId};
use crate::model::vision::Resolution;
use crate::optimizer::bayes::{BayesOpt, BayesOptConfig};
use crate::optimizer::objective::{ConfigEvaluator, Objective};
use crate::optimizer::space::SearchSpace;
use crate::optimizer::surrogate::SurrogateModel;
use crate::sim::engine::{SimConfig, Simulator};
use crate::util::argp::{flag, opt, ArgError, Cli, CmdSpec};
use crate::util::rng::Rng;
use crate::workload::synthetic::SyntheticWorkload;
use crate::workload::Workload;

fn cli() -> Cli {
    Cli::new("epdserve", "EPD-disaggregated LMM serving (ICML 2025 reproduction)")
        .cmd(CmdSpec {
            name: "serve",
            about: "start the real engine with an HTTP frontend",
            opts: vec![
                opt("artifacts", Some("artifacts"), "AOT artifacts directory"),
                opt("mode", Some("epd"), "epd | distserve | vllm"),
                opt("topology", Some("2E1P1D"), "instance topology, e.g. 5E2P1D"),
                opt("addr", Some("127.0.0.1:8072"), "listen address"),
                flag("role-switching", "enable dynamic role switching"),
                opt(
                    "router",
                    Some("off"),
                    "front-door admission: off | on (on sheds with 429 when the projected TTFT/TPOT misses --slo-ttft/--slo-tpot)",
                ),
                opt("slo-ttft", Some("inf"), "router TTFT target (s)"),
                opt("slo-tpot", Some("inf"), "router TPOT target (s)"),
                flag(
                    "supervise",
                    "enable worker supervision: heartbeats, crash sweeps, exactly-once redispatch, deadline watchdog",
                ),
                opt(
                    "drain-timeout-ms",
                    Some("0"),
                    "graceful-shutdown drain bound in ms (0 = immediate shutdown)",
                ),
                opt(
                    "engine-faults",
                    Some("off"),
                    "engine chaos injection: off | wave | wave:<seed> (seeded worker-kill wave; implies supervised recovery paths are exercised)",
                ),
                opt(
                    "health",
                    Some("off"),
                    "engine health layer: off | on (breaker-gated typed submits + cluster retry budget; implies --supervise)",
                ),
            ],
            positional: vec![],
        })
        .cmd(CmdSpec {
            name: "generate",
            about: "one-shot generation through an in-process engine",
            opts: vec![
                opt("artifacts", Some("artifacts"), "AOT artifacts directory"),
                opt("prompt", Some("describe the image"), "text prompt"),
                opt("images", Some("2"), "synthetic images to attach"),
                opt("max-tokens", Some("16"), "tokens to generate"),
                opt("topology", Some("2E1P1D"), "instance topology"),
                opt("tenant", Some("0"), "tenant id stamped on the request"),
                opt("priority", Some("interactive"), "interactive | batch"),
            ],
            positional: vec![],
        })
        .cmd(CmdSpec {
            name: "simulate",
            about: "run the discrete-event cluster simulator",
            opts: vec![
                opt("model", Some("minicpm"), "minicpm | internvl2-8b | internvl2-26b | ultravox"),
                opt("mode", Some("epd"), "epd | distserve | vllm"),
                opt("topology", Some("5E2P1D"), "instance topology"),
                opt("rate", Some("0.5"), "Poisson arrival rate (req/s)"),
                opt("requests", Some("100"), "number of requests"),
                opt("images", Some("2"), "images per request"),
                opt("output-tokens", Some("10"), "output length"),
                opt("device", Some("a100"), "a100 | npu"),
                opt(
                    "workload",
                    Some("synthetic"),
                    "synthetic | mixed-tenant | cluster-scale | diurnal (cluster-scale/diurnal run on the 64-instance reference cluster; ignore --mode/--topology/--images/--output-tokens)",
                ),
                opt(
                    "router",
                    Some("off"),
                    "front-door admission: off | on (on sheds/degrades against --slo-ttft/--slo-tpot)",
                ),
                opt(
                    "faults",
                    Some("off"),
                    "chaos injection: off | wave | wave:<seed> (seeded crash/link-degrade/straggler/OOM wave; replays bit-for-bit per seed)",
                ),
                opt(
                    "health",
                    Some("off"),
                    "health-aware control plane: off | on | replan (on = circuit breakers + P95 hedged dispatch + cluster retry budget; replan adds fault-aware replanning via role switching)",
                ),
                flag("no-irp", "disable intra-request parallelism"),
                flag(
                    "no-timelines",
                    "skip per-request timelines; report sketch-derived percentiles in O(1) memory",
                ),
                flag("goodput", "search for goodput instead of one run"),
                opt("slo-ttft", Some("2.6"), "TTFT SLO (s)"),
                opt("slo-tpot", Some("0.04"), "TPOT SLO (s)"),
            ],
            positional: vec![],
        })
        .cmd(CmdSpec {
            name: "optimize",
            about: "black-box config optimization over the simulator (Eq. 1)",
            opts: vec![
                opt("model", Some("minicpm"), "target model"),
                opt("gpus", Some("8"), "total GPUs"),
                opt("budget", Some("16"), "evaluation budget"),
                opt("images", Some("6"), "images per request"),
                opt("requests", Some("50"), "requests per evaluation"),
                opt("threads", Some("0"), "parallel sim evaluations for --sweep (0 = all cores)"),
                flag("random", "random search instead of Bayesian"),
                flag("sweep", "exhaustive parallel sweep over every topology (uses --threads)"),
                flag(
                    "surrogate",
                    "with --sweep: GP-prefilter the grid — simulate a few seed points, EI-rank the rest, simulate only the top candidates",
                ),
            ],
            positional: vec![],
        })
        .cmd(CmdSpec {
            name: "repro",
            about: "regenerate a paper table/figure (fig2..fig12, table1..table8, all)",
            opts: vec![],
            positional: vec![("id", "experiment id, e.g. fig5 or all")],
        })
        .cmd(CmdSpec {
            name: "capacity",
            about: "query the GPU memory model",
            opts: vec![
                opt("model", Some("minicpm"), "target model"),
                opt("resolution", Some("4032x3024"), "image resolution WxH"),
                opt("images", Some("10"), "images per request"),
                opt("kv-frac", Some("0.8"), "KV cache fraction of free memory"),
            ],
            positional: vec![],
        })
}

/// Entry point (called from main).
pub fn run() {
    crate::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cli().parse(&argv) {
        Ok(args) => {
            if let Err(e) = dispatch(&args) {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
        Err(ArgError(msg)) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    }
}

fn parse_model(s: &str) -> anyhow::Result<LmmSpec> {
    ModelId::parse(s)
        .map(LmmSpec::get)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{s}'"))
}

fn parse_resolution(s: &str) -> anyhow::Result<Resolution> {
    let (w, h) = s
        .split_once('x')
        .ok_or_else(|| anyhow::anyhow!("resolution must be WxH"))?;
    Ok(Resolution::new(w.parse()?, h.parse()?))
}

fn parse_router(s: &str) -> anyhow::Result<RouterPolicy> {
    RouterPolicy::parse(s).ok_or_else(|| anyhow::anyhow!("--router must be off | on"))
}

fn epd_config(mode: &str, topology: &str) -> anyhow::Result<EpdConfig> {
    let mode = DeploymentMode::parse(mode).ok_or_else(|| anyhow::anyhow!("bad mode"))?;
    let topo = Topology::parse(topology).ok_or_else(|| anyhow::anyhow!("bad topology"))?;
    let mut cfg = match mode {
        DeploymentMode::Epd => EpdConfig::epd(topo, 1, 1, 128),
        DeploymentMode::PdDisagg => {
            EpdConfig::distserve(topo.prefill.max(topo.encode), topo.decode.max(1), 1, 128)
        }
        DeploymentMode::Aggregated => EpdConfig::aggregated(topo.total().max(1), 64),
    };
    cfg.mode = mode;
    Ok(cfg)
}

fn dispatch(args: &crate::util::argp::Args) -> anyhow::Result<()> {
    match args.cmd.as_str() {
        "serve" => {
            let mut cfg = epd_config(args.str("mode"), args.str("topology"))?;
            cfg.role_switching = args.flag("role-switching");
            cfg.router = parse_router(args.str("router"))?;
            cfg.router_slo_ttft = args.f64("slo-ttft");
            cfg.router_slo_tpot = args.f64("slo-tpot");
            cfg.supervise = args.flag("supervise");
            cfg.drain_timeout_ms = args.u64("drain-timeout-ms");
            match args.str("engine-faults") {
                "off" => {}
                s if s == "wave" || s.starts_with("wave:") => {
                    // Same schema as `simulate --faults`: zero means off,
                    // so the bare form picks a fixed non-zero default.
                    let seed = match s.strip_prefix("wave:") {
                        Some(v) => v.parse::<u64>().map_err(|_| {
                            anyhow::anyhow!("--engine-faults wave:<seed> needs a number")
                        })?,
                        None => 0xC4A05,
                    };
                    if seed == 0 {
                        anyhow::bail!(
                            "--engine-faults wave:<seed> needs a non-zero seed (0 means off)"
                        );
                    }
                    cfg.engine_fault_seed = seed;
                    // A kill wave without supervision just loses requests;
                    // chaos serving implies the recovery paths.
                    cfg.supervise = true;
                }
                other => {
                    anyhow::bail!("unknown --engine-faults '{other}' (off | wave | wave:<seed>)")
                }
            }
            match args.str("health") {
                "off" => {}
                "on" => {
                    // Breaker-gated typed submits plus a cluster-wide
                    // redispatch budget; hedged dispatch is sim-only (the
                    // pull-based engine has no dispatch point to duplicate).
                    // The breaker is fed by supervision crash sweeps, so
                    // health implies --supervise.
                    cfg.health_breaker = true;
                    cfg.retry_budget_per_s = 4.0;
                    cfg.supervise = true;
                }
                other => anyhow::bail!("unknown --health '{other}' (off | on)"),
            }
            let engine = Arc::new(crate::engine::serve::EpdEngine::start(
                crate::engine::serve::EngineConfig::new(args.str("artifacts"), cfg),
            )?);
            let server = crate::engine::http::HttpServer::serve(engine, args.str("addr"))?;
            println!("serving on http://{} — POST /v1/completions", server.addr);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        "generate" => {
            let cfg = epd_config("epd", args.str("topology"))?;
            let engine = crate::engine::serve::EpdEngine::start(
                crate::engine::serve::EngineConfig::new(args.str("artifacts"), cfg),
            )?;
            let priority = Priority::parse(args.str("priority"))
                .ok_or_else(|| anyhow::anyhow!("--priority must be interactive | batch"))?;
            let req = SubmitRequest::new(args.str("prompt"))
                .images(args.u64("images") as u32)
                .max_tokens(args.u64("max-tokens") as u32)
                .tenant(args.u64("tenant") as u32)
                .priority(priority)
                .seed(0x5EED);
            let (_, rx) = engine.submit_request(req)?;
            let resp = engine.wait(&rx, 0)?;
            println!("tokens: {:?}", resp.tokens);
            println!("text:   {:?}", resp.text);
            println!("latency: {:.3}s", resp.latency);
            engine.shutdown();
            Ok(())
        }
        "simulate" => {
            let spec = parse_model(args.str("model"))?;
            let device = match args.str("device") {
                "npu" => DeviceSpec::npu_910b3(),
                _ => DeviceSpec::a100(),
            };
            let (w, mut epd): (Box<dyn Workload>, EpdConfig) = match args.str("workload") {
                "cluster-scale" => {
                    // The cluster-scale workload targets the 64-instance
                    // reference topology; --mode/--topology are ignored
                    // (like --images/--output-tokens).
                    use crate::workload::cluster_scale::ClusterScaleWorkload;
                    (
                        Box::new(ClusterScaleWorkload::default()),
                        EpdConfig::epd(ClusterScaleWorkload::topology64(), 1, 1, 128),
                    )
                }
                "diurnal" => {
                    // Multi-day diurnal trace with flash crowds, over the
                    // cluster-scale mix (same reference topology).
                    use crate::workload::cluster_scale::ClusterScaleWorkload;
                    use crate::workload::diurnal::DiurnalWorkload;
                    (
                        Box::new(DiurnalWorkload::default()),
                        EpdConfig::epd(ClusterScaleWorkload::topology64(), 1, 1, 128),
                    )
                }
                "mixed-tenant" => (
                    Box::new(crate::workload::MixedTenantWorkload::default()),
                    epd_config(args.str("mode"), args.str("topology"))?,
                ),
                "synthetic" => (
                    Box::new(SyntheticWorkload::new(
                        args.u64("images") as u32,
                        args.u64("output-tokens") as u32,
                    )),
                    epd_config(args.str("mode"), args.str("topology"))?,
                ),
                other => anyhow::bail!("unknown workload '{other}'"),
            };
            epd.irp = !args.flag("no-irp");
            epd.router = parse_router(args.str("router"))?;
            let router_on = epd.router == RouterPolicy::On;
            if router_on {
                // The router projects against the same targets the report
                // scores (--slo-ttft/--slo-tpot).
                epd.router_slo_ttft = args.f64("slo-ttft");
                epd.router_slo_tpot = args.f64("slo-tpot");
            }
            match args.str("faults") {
                "off" => {}
                s if s == "wave" || s.starts_with("wave:") => {
                    // A zero seed means "off" in the config schema, so the
                    // bare form picks a fixed non-zero default.
                    let seed = match s.strip_prefix("wave:") {
                        Some(v) => v
                            .parse::<u64>()
                            .map_err(|_| anyhow::anyhow!("--faults wave:<seed> needs a number"))?,
                        None => 0xC4A05,
                    };
                    if seed == 0 {
                        anyhow::bail!("--faults wave:<seed> needs a non-zero seed (0 means off)");
                    }
                    epd.fault_seed = seed;
                }
                other => anyhow::bail!("unknown --faults '{other}' (off | wave | wave:<seed>)"),
            }
            match args.str("health") {
                "off" => {}
                s @ ("on" | "replan") => {
                    // Mirrors the health-aware arm of perf_health_routing:
                    // breakers + quarantine, P95 hedged dispatch, and a
                    // cluster-wide redispatch budget.
                    epd.health_breaker = true;
                    epd.hedge_quantile = 0.95;
                    epd.retry_budget_per_s = 4.0;
                    if s == "replan" {
                        epd.health_replan = true;
                        epd.role_switching = true;
                    }
                }
                other => anyhow::bail!("unknown --health '{other}' (off | on | replan)"),
            }
            let mut cfg = SimConfig::new(spec.clone(), device, epd);
            let slo = Slo::new(args.f64("slo-ttft"), args.f64("slo-tpot"));
            if args.flag("no-timelines") {
                cfg.record_timelines = false;
                cfg.streamed_slo = Some(slo);
            }
            if args.flag("goodput") {
                let n = args.usize("requests");
                let result = find_goodput(
                    |rate| {
                        let mut rng = Rng::new(42);
                        let reqs = w.generate(&spec, n, rate, &mut rng);
                        Simulator::run(&cfg, &reqs).slo_attainment(slo)
                    },
                    0.05,
                    0.9,
                    0.05,
                );
                println!(
                    "goodput: {:.3} req/s (attainment {:.3}, {} evals)",
                    result.goodput, result.attainment, result.evals
                );
            } else {
                let mut rng = Rng::new(42);
                let reqs = w.generate(&spec, args.usize("requests"), args.f64("rate"), &mut rng);
                let out = Simulator::run(&cfg, &reqs);
                println!("finished:   {}/{}", out.finished_requests(), reqs.len());
                println!("mean TTFT:  {:.3}s", out.mean_ttft());
                println!("mean TPOT:  {:.4}s", out.mean_tpot());
                println!("SLO attain: {:.3}", out.slo_attainment(slo));
                println!(
                    "switches:   {} ({} plans / {} steps)",
                    out.role_switches, out.reallocation.plans, out.reallocation.planned_steps
                );
                if router_on {
                    let r = &out.router;
                    println!(
                        "router:     text-bypass {} mm {} shed {} degraded {} held {} (peak {})",
                        r.text_bypass, r.mm_routed, r.shed, r.degraded, r.held, r.peak_held
                    );
                }
                if !cfg.faults.is_empty() {
                    let r = &out.resilience;
                    println!(
                        "faults:     {} crashes / {} link degradations / {} ooms / {} stragglers",
                        r.crashes, r.link_degradations, r.encoder_ooms, r.straggler_instances
                    );
                    println!(
                        "resilience: lost {} retried {} retargeted {}  recovery {:.1}s  SLO dip {:.3}",
                        r.requests_lost,
                        r.requests_retried,
                        r.requests_retargeted,
                        r.recovery_seconds,
                        r.slo_dip
                    );
                }
                if args.str("health") != "off" {
                    let h = &out.resilience;
                    println!(
                        "health:     breaker opens {} quarantines {} probes {}  hedges {} (won {} / cancelled {})  budget sheds {}",
                        h.breaker_opens,
                        h.quarantines,
                        h.breaker_probes,
                        h.hedges_issued,
                        h.hedges_won,
                        h.hedges_cancelled,
                        h.retry_budget_exhausted
                    );
                }
                if !out.timelines_recorded {
                    let s = &out.streamed;
                    println!(
                        "TTFT p50/p90/p99: {:.3}/{:.3}/{:.3}s  TPOT p99: {:.4}s",
                        s.ttft.quantile(0.5),
                        s.ttft.quantile(0.9),
                        s.ttft.quantile(0.99),
                        s.tpot.quantile(0.99),
                    );
                    println!(
                        "percentiles are sketch-derived (±{:.0}% relative error; timelines off)",
                        s.ttft.relative_accuracy() * 100.0
                    );
                    println!(
                        "events: {}  peak live requests: {}",
                        out.events_processed, out.peak_live_requests
                    );
                }
            }
            Ok(())
        }
        "optimize" => {
            let spec = parse_model(args.str("model"))?;
            let w = SyntheticWorkload::new(args.u64("images") as u32, 10);
            let ev = ConfigEvaluator {
                spec: spec.clone(),
                device: DeviceSpec::a100(),
                workload: &w,
                objective: Objective {
                    beta: 0.0,
                    gpu_cost: 1.0,
                    slo: Slo::new(3.9, 0.06),
                    threshold: 0.9,
                },
                n_requests: args.usize("requests"),
                seed: 42,
            };
            let space = SearchSpace::paper_default(args.u64("gpus") as u32);
            if args.flag("sweep") {
                // Exhaustive topology sweep, fanned out across scoped
                // worker threads (results are bit-identical at any
                // thread count — each sim is deterministic per seed).
                let threads = match args.usize("threads") {
                    0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
                    t => t,
                };
                let points = space.topology_grid();
                if args.flag("surrogate") {
                    // GP prefilter: honestly simulate a strided handful
                    // of seed points, train the surrogate on them,
                    // EI-rank the remainder, and honestly simulate only
                    // the top-ranked (plus any past the variance floor).
                    let mut model = SurrogateModel::new(2.0);
                    let stride = (points.len() / 5).max(1);
                    let seeds: Vec<usize> = (0..points.len()).step_by(stride).collect();
                    let mut evaluated: Vec<(usize, f64)> = Vec::new();
                    for &i in &seeds {
                        let v = ev.goodput(&points[i]);
                        model.observe(points[i].features(), v);
                        evaluated.push((i, v));
                    }
                    let rest: Vec<usize> =
                        (0..points.len()).filter(|i| !seeds.contains(i)).collect();
                    let feats: Vec<Vec<f64>> =
                        rest.iter().map(|&i| points[i].features()).collect();
                    let sel = model.select(&feats, 5, 0.25);
                    for ri in sel.chosen {
                        let i = rest[ri];
                        let v = ev.goodput(&points[i]);
                        model.observe(points[i].features(), v);
                        evaluated.push((i, v));
                    }
                    for &(i, v) in &evaluated {
                        println!("  {}  goodput {:.3} req/s", points[i].topology, v);
                    }
                    let &(bi, bv) = evaluated
                        .iter()
                        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                        .unwrap();
                    println!(
                        "best topology: {} at {:.3} req/s ({} simulated of {} candidates; {} GP-prefiltered away)",
                        points[bi].topology,
                        bv,
                        evaluated.len(),
                        points.len(),
                        points.len() - evaluated.len()
                    );
                    return Ok(());
                }
                let values = ev.goodput_many(&points, threads);
                let best = values
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i)
                    .unwrap();
                for (p, v) in points.iter().zip(values.iter()) {
                    println!("  {}  goodput {:.3} req/s", p.topology, v);
                }
                println!(
                    "best topology: {} at {:.3} req/s ({} candidates, {} threads)",
                    points[best].topology,
                    values[best],
                    points.len(),
                    threads
                );
                return Ok(());
            }
            let opt = BayesOpt::new(
                space,
                BayesOptConfig { budget: args.usize("budget"), ..Default::default() },
            );
            let result = if args.flag("random") {
                opt.random_search(|p| ev.goodput(p))
            } else {
                opt.run(|p| ev.goodput(p))
            };
            println!(
                "best config: {} (batch E{}/P{}/D{}, {}, irp={})",
                result.best.topology,
                result.best.batch_e,
                result.best.batch_p,
                result.best.batch_d,
                result.best.queue.name(),
                result.best.irp
            );
            println!(
                "best goodput: {:.3} req/s over {} evals",
                result.best_value,
                result.history.len()
            );
            Ok(())
        }
        "repro" => {
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
            let tables = crate::repro::run(id)?;
            for t in tables {
                t.emit();
            }
            Ok(())
        }
        "capacity" => {
            let spec = parse_model(args.str("model"))?;
            let res = parse_resolution(args.str("resolution"))?;
            let images = args.u64("images") as u32;
            let kv = args.f64("kv-frac");
            let m = MemoryModel::new(spec, DeviceSpec::a100());
            for (name, node) in [
                ("DistServe/vLLM (colocated)", NodeKind::Colocated),
                ("EPD encode node", NodeKind::EncodeOnly),
                ("EPD prefill node", NodeKind::LlmOnly),
            ] {
                let (imgs, why1) = m.max_images_per_request(node, res, kv, 22);
                let (batch, why2) = m.max_batch(node, images, res, kv);
                println!(
                    "{name:<28} max images/req: {imgs:>6} ({why1:?})   max batch @{images} img: {batch:>5} ({why2:?})"
                );
            }
            Ok(())
        }
        other => anyhow::bail!("unhandled command {other}"),
    }
}
