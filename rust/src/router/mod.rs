//! The SLO-aware multi-path front door (ROADMAP item 1): a
//! router/admission tier shared by the simulator and the real engine.
//!
//! Three mechanisms, modelled on the vllm-ascend "EPD Load Balance
//! Proxy" design:
//!
//! - **Multi-path routing** — text-only requests bypass the encoder
//!   stage entirely and dispatch straight toward prefill; multimodal
//!   requests go least-loaded across encoder instances.
//! - **Per-tenant weighted fairness + priority classes** — every
//!   request carries a tenant id and an `interactive | batch` class;
//!   [`fair::FairQueue`] runs weighted deficit round robin per tenant
//!   inside per-class priority bands.
//! - **SLO-aware admission** — [`admission::decide`] projects TTFT/TPOT
//!   for an arriving request from live backlogs plus profiled service
//!   EWMAs and sheds (HTTP 429 in the engine, `rejected` in the sim) or
//!   degrades when the projection misses SLO.
//!
//! Everything defaults off (`router = "off"` in TOML): with the router
//! off the submit path is bit-for-bit the legacy single path
//! (property-tested in `rust/tests/property_router.rs`).

pub mod admission;
pub mod fair;
pub mod health;

pub use admission::{decide, AdmissionDecision, AdmissionOutlook};
pub use fair::FairQueue;
pub use health::{BreakerState, HealthConfig, HealthTracker, HedgeTracker, RetryBudget};

use crate::core::config::{EpdConfig, RouterPolicy};
use crate::core::slo::Slo;
use crate::util::json::Json;

/// Parse a `"tenant:weight,..."` spec (the `router_tenant_weights` TOML
/// key) into `(tenant, weight)` pairs. Weights are clamped to >= 1;
/// an empty string is the empty list.
pub fn parse_tenant_weights(s: &str) -> anyhow::Result<Vec<(u32, u32)>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (t, w) = part
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("expected 'tenant:weight', got '{part}'"))?;
        let t: u32 = t.trim().parse().map_err(|_| anyhow::anyhow!("bad tenant id '{t}'"))?;
        let w: u32 = w.trim().parse().map_err(|_| anyhow::anyhow!("bad weight '{w}'"))?;
        out.push((t, w.max(1)));
    }
    out.sort_unstable();
    Ok(out)
}

/// Runtime router configuration distilled from the `router_*` keys of
/// [`EpdConfig`] (the same pattern `sim::fault::FaultPlan::from_epd`
/// uses for the chaos keys). `None` means the router is off and the
/// front door must not exist at all.
#[derive(Debug, Clone, PartialEq)]
pub struct RouterConfig {
    /// Projection targets; `INFINITY` on an axis disables shedding there.
    pub slo: Slo,
    /// Multiplier on both targets before comparing the projection.
    pub headroom: f64,
    /// Per-instance queue-depth window the door dispatches into.
    pub depth: u32,
    /// Degrade mild interactive overload instead of shedding it.
    pub degrade: bool,
    /// `max_tokens` cap applied to degraded requests.
    pub degrade_tokens: u32,
    /// Floor for the shed `retry_after_ms` hint.
    pub retry_after_ms: u64,
    /// Deficit weight for unlisted tenants.
    pub default_weight: u32,
    /// Per-tenant deficit weights, sorted by tenant id.
    pub weights: Vec<(u32, u32)>,
}

impl RouterConfig {
    /// Build from the flat config; `None` when `router = "off"`.
    /// An unparseable weight spec degrades to the default weight for
    /// everyone (`EpdConfig::from_toml` already rejects it loudly).
    pub fn from_epd(epd: &EpdConfig) -> Option<RouterConfig> {
        if epd.router == RouterPolicy::Off {
            return None;
        }
        Some(RouterConfig {
            slo: Slo::new(epd.router_slo_ttft, epd.router_slo_tpot),
            headroom: epd.router_headroom,
            depth: epd.router_depth.max(1),
            degrade: epd.router_degrade,
            degrade_tokens: epd.router_degrade_tokens.max(1),
            retry_after_ms: epd.router_retry_after_ms,
            default_weight: epd.router_default_weight.max(1),
            weights: parse_tenant_weights(&epd.router_tenant_weights).unwrap_or_default(),
        })
    }

    /// Deficit weight for `tenant`.
    pub fn weight_of(&self, tenant: u32) -> u32 {
        match self.weights.binary_search_by_key(&tenant, |&(t, _)| t) {
            Ok(i) => self.weights[i].1,
            Err(_) => self.default_weight,
        }
    }
}

/// Front-door counters, reported in `SimOutcome::router` (all zero when
/// `router = "off"` — the dormancy property tests assert exactly that)
/// and in the engine's `/metrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RouterStats {
    /// Text-only requests that skipped the encode stage.
    pub text_bypass: u64,
    /// Multimodal requests routed through the encoder path.
    pub mm_routed: u64,
    /// Requests shed by admission control.
    pub shed: u64,
    /// Requests served degraded (capped tokens, batch class).
    pub degraded: u64,
    /// Dispatches that had waited in the front-door fair queues.
    pub held: u64,
    /// Peak simultaneous occupancy of the fair queues.
    pub peak_held: u64,
}

impl RouterStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("text_bypass", Json::num(self.text_bypass as f64)),
            ("mm_routed", Json::num(self.mm_routed as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("degraded", Json::num(self.degraded as f64)),
            ("held", Json::num(self.held as f64)),
            ("peak_held", Json::num(self.peak_held as f64)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::topology::Topology;

    #[test]
    fn weight_spec_parses_and_sorts() {
        let w = parse_tenant_weights("7:2, 0:4").unwrap();
        assert_eq!(w, vec![(0, 4), (7, 2)]);
        assert!(parse_tenant_weights("").unwrap().is_empty());
        assert!(parse_tenant_weights("0;4").is_err());
        assert!(parse_tenant_weights("x:1").is_err());
        // Zero weights clamp to 1 (a zero-weight tenant would starve).
        assert_eq!(parse_tenant_weights("3:0").unwrap(), vec![(3, 1)]);
    }

    #[test]
    fn from_epd_gates_on_policy() {
        let mut epd = EpdConfig::epd(Topology::new(2, 1, 1), 1, 1, 8);
        assert!(RouterConfig::from_epd(&epd).is_none(), "off => no front door");
        epd.router = RouterPolicy::On;
        epd.router_tenant_weights = "1:3".to_string();
        let rc = RouterConfig::from_epd(&epd).unwrap();
        assert_eq!(rc.weight_of(1), 3);
        assert_eq!(rc.weight_of(9), 1, "unlisted tenants get the default");
        assert_eq!(rc.slo.ttft, f64::INFINITY);
    }

    #[test]
    fn stats_json_shape() {
        let s = RouterStats { shed: 3, ..RouterStats::default() };
        let j = s.to_json();
        assert_eq!(j.get("shed").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("text_bypass").and_then(|v| v.as_f64()), Some(0.0));
    }
}
