//! Per-tenant weighted deficit round robin inside per-class priority
//! bands: the front door's fairness engine.
//!
//! Two bands (interactive, then batch — see `Priority::band`); within a
//! band each tenant owns a FIFO and a deficit counter. A tenant's
//! quantum is its weight, spent one item per pop; the cursor only
//! advances when the quantum is exhausted or the tenant's FIFO empties,
//! so over any window in which a set of tenants stays backlogged each
//! receives service proportional to its weight, and no backlogged
//! tenant waits more than one full round (the classic DRR bound —
//! property-tested in `rust/tests/property_router.rs`). Entirely
//! deterministic: tenant order is first-appearance order.

use std::collections::VecDeque;

use crate::core::request::Priority;

#[derive(Debug, Clone)]
struct TenantQueue<T> {
    tenant: u32,
    weight: u64,
    deficit: u64,
    items: VecDeque<T>,
}

#[derive(Debug, Clone)]
struct Band<T> {
    tenants: Vec<TenantQueue<T>>,
    cursor: usize,
    len: usize,
}

impl<T> Band<T> {
    fn new() -> Band<T> {
        Band { tenants: Vec::new(), cursor: 0, len: 0 }
    }

    fn push(&mut self, tenant: u32, weight: u64, item: T) {
        self.len += 1;
        if let Some(tq) = self.tenants.iter_mut().find(|t| t.tenant == tenant) {
            tq.items.push_back(item);
            return;
        }
        let mut items = VecDeque::new();
        items.push_back(item);
        self.tenants.push(TenantQueue { tenant, weight: weight.max(1), deficit: 0, items });
    }

    fn pop(&mut self) -> Option<T> {
        if self.len == 0 {
            return None;
        }
        let n = self.tenants.len();
        loop {
            let i = self.cursor % n;
            if self.tenants[i].items.is_empty() {
                // Idle tenants forfeit their deficit (standard DRR: only
                // backlogged queues accumulate service credit).
                self.tenants[i].deficit = 0;
                self.cursor = (i + 1) % n;
                continue;
            }
            if self.tenants[i].deficit == 0 {
                self.tenants[i].deficit = self.tenants[i].weight;
            }
            self.tenants[i].deficit -= 1;
            let item = self.tenants[i].items.pop_front();
            self.len -= 1;
            if self.tenants[i].deficit == 0 || self.tenants[i].items.is_empty() {
                self.tenants[i].deficit = 0;
                self.cursor = (i + 1) % n;
            }
            return item;
        }
    }
}

/// The front door's holding structure: weighted-fair per-tenant queues
/// under strict class-band priority (interactive drains before batch).
#[derive(Debug, Clone)]
pub struct FairQueue<T> {
    bands: [Band<T>; 2],
    default_weight: u32,
    /// `(tenant, weight)` overrides, sorted by tenant id.
    weights: Vec<(u32, u32)>,
}

impl<T> FairQueue<T> {
    pub fn new(default_weight: u32, mut weights: Vec<(u32, u32)>) -> FairQueue<T> {
        weights.sort_unstable();
        FairQueue {
            bands: [Band::new(), Band::new()],
            default_weight: default_weight.max(1),
            weights,
        }
    }

    /// Deficit weight for `tenant`.
    pub fn weight_of(&self, tenant: u32) -> u32 {
        match self.weights.binary_search_by_key(&tenant, |&(t, _)| t) {
            Ok(i) => self.weights[i].1.max(1),
            Err(_) => self.default_weight,
        }
    }

    pub fn push(&mut self, tenant: u32, class: Priority, item: T) {
        let w = self.weight_of(tenant) as u64;
        self.bands[class.band()].push(tenant, w, item);
    }

    /// Pop the next item: interactive band first, weighted-DRR within.
    pub fn pop(&mut self) -> Option<T> {
        for band in &mut self.bands {
            if let Some(item) = band.pop() {
                return Some(item);
            }
        }
        None
    }

    pub fn len(&self) -> usize {
        self.bands.iter().map(|b| b.len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interactive_drains_before_batch() {
        let mut fq: FairQueue<u32> = FairQueue::new(1, vec![]);
        fq.push(0, Priority::Batch, 1);
        fq.push(0, Priority::Interactive, 2);
        fq.push(1, Priority::Interactive, 3);
        assert_eq!(fq.pop(), Some(2));
        assert_eq!(fq.pop(), Some(3));
        assert_eq!(fq.pop(), Some(1));
        assert_eq!(fq.pop(), None);
        assert!(fq.is_empty());
    }

    #[test]
    fn weighted_shares_are_proportional() {
        // Tenants 0/1/2 at weights 1/2/4, all saturated: any window of 7
        // consecutive pops serves exactly (1, 2, 4).
        let mut fq: FairQueue<u32> = FairQueue::new(1, vec![(1, 2), (2, 4)]);
        for i in 0..70u32 {
            for t in 0..3u32 {
                fq.push(t, Priority::Interactive, t * 1000 + i);
            }
        }
        let mut counts = [0u32; 3];
        for _ in 0..70 {
            let v = fq.pop().unwrap();
            counts[(v / 1000) as usize] += 1;
        }
        assert_eq!(counts, [10, 20, 40]);
    }

    #[test]
    fn fifo_within_tenant() {
        let mut fq: FairQueue<u32> = FairQueue::new(1, vec![]);
        fq.push(5, Priority::Interactive, 1);
        fq.push(5, Priority::Interactive, 2);
        fq.push(5, Priority::Interactive, 3);
        assert_eq!(fq.pop(), Some(1));
        assert_eq!(fq.pop(), Some(2));
        assert_eq!(fq.pop(), Some(3));
    }

    #[test]
    fn idle_tenant_forfeits_deficit() {
        let mut fq: FairQueue<u32> = FairQueue::new(1, vec![(0, 4)]);
        fq.push(0, Priority::Interactive, 1);
        fq.push(1, Priority::Interactive, 2);
        // Tenant 0 empties mid-quantum; tenant 1 must still be served next.
        assert_eq!(fq.pop(), Some(1));
        assert_eq!(fq.pop(), Some(2));
        // Refill: no leftover credit lets tenant 0 burst past its weight.
        for i in 10..20u32 {
            fq.push(0, Priority::Interactive, i);
            fq.push(1, Priority::Interactive, 100 + i);
        }
        let mut zero_run = 0;
        let mut max_run = 0;
        for _ in 0..20 {
            let v = fq.pop().unwrap();
            if v < 100 {
                zero_run += 1;
                max_run = max_run.max(zero_run);
            } else {
                zero_run = 0;
            }
        }
        assert!(max_run <= 4, "tenant 0 served at most its weight per round");
    }
}
