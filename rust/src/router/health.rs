//! The shared per-instance health layer behind the health-aware control
//! plane: one circuit-breaker state machine consumed by *both* engines —
//! the simulator (fed by `sim::fault` crash/OOM events and `SwitchDone`
//! recoveries) and the real engine (fed by `engine::supervise` panic
//! sweeps and heartbeat deaths) — so chaos-bench results predict real
//! deployment behavior.
//!
//! Per instance, a [`HealthTracker`] runs the classic breaker cycle
//!
//! ```text
//!            failure                    recovery / open_secs elapse
//!  Closed ───────────▶ Open ──────────────────────────▶ HalfOpen
//!    ▲                   │                                  │
//!    │ probe succeeds    │ flap_threshold failures          │ probe fails
//!    └───────────────────┤ inside flap_window               ▼
//!                        ▼                                Open
//!                   Quarantined ──(seeded probation expires)──▶ HalfOpen
//! ```
//!
//! plus two cluster-wide guards: a [`RetryBudget`] token bucket capping
//! the redispatch rate a crash wave may generate, and a [`HedgeTracker`]
//! deriving per-stage hedge thresholds from streaming quantile sketches
//! ([`crate::util::stats::QuantileSketch`]).
//!
//! Everything here is deterministic — time is caller-supplied `f64`
//! seconds (virtual in the simulator, wall-clock in the engine), and the
//! quarantine probation backoff is a pure function of `(seed, instance,
//! offence)` — and dormant by default: [`HealthConfig::from_epd`] returns
//! `None` until one of the `health_*` / `hedge_*` / `retry_budget_*`
//! keys leaves its default, and a `None` config wires no tracker at all
//! (property-tested in `rust/tests/property_health.rs`).

use crate::core::config::EpdConfig;
use crate::util::rng::Rng;
use crate::util::stats::QuantileSketch;

/// Fallback jitter seed when no `fault_seed` is armed (probation backoff
/// must stay deterministic even in fault-free configurations).
const DEFAULT_HEALTH_SEED: u64 = 0x4EA1_7500_0000_0001;

/// Cap on the probation-doubling exponent (`probation_secs << 6` max).
const MAX_PROBATION_SHIFT: u32 = 6;

/// Resolved health-layer tunables (the `health_*` / `hedge_*` /
/// `retry_budget_*` block of [`EpdConfig`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HealthConfig {
    /// Circuit-breaker dispatch filtering (skip Open, probe Half-Open,
    /// quarantine flappers).
    pub breaker: bool,
    /// Fault-aware replanning: unhealthy instances count zero capacity
    /// and a crash forces an out-of-band plan tick.
    pub replan: bool,
    /// Seconds an instance stays Open after a failure before probing.
    pub open_secs: f64,
    /// Probe budget granted on the Open → Half-Open transition.
    pub half_open_probes: u32,
    /// Failures inside `flap_window` that escalate to quarantine.
    pub flap_threshold: u32,
    /// Width (seconds) of the flapping-detection window.
    pub flap_window: f64,
    /// Base quarantine probation; doubles per repeat offence (seeded
    /// jitter on top, capped at `base << 6`).
    pub probation_secs: f64,
    /// Hedge trigger quantile in (0, 1]; 0 disables hedged dispatch.
    pub hedge_quantile: f64,
    /// Stage-wait samples required before hedge thresholds engage.
    pub hedge_min_samples: u64,
    /// Cluster-wide redispatch tokens per second; 0 disables the budget.
    pub retry_budget_per_s: f64,
    /// Token-bucket burst capacity.
    pub retry_budget_burst: f64,
    /// Jitter seed for the probation backoff (the fault seed when armed).
    pub seed: u64,
}

impl HealthConfig {
    /// Resolve from config. `None` — the default — means the health layer
    /// is entirely absent: no tracker, no budget, no sketches, bit-for-bit
    /// today's behavior.
    pub fn from_epd(epd: &EpdConfig) -> Option<HealthConfig> {
        let dormant = !epd.health_breaker
            && !epd.health_replan
            && epd.hedge_quantile <= 0.0
            && epd.retry_budget_per_s <= 0.0;
        if dormant {
            return None;
        }
        Some(HealthConfig {
            breaker: epd.health_breaker,
            replan: epd.health_replan,
            open_secs: epd.health_open_secs.max(0.0),
            half_open_probes: epd.health_probes.max(1),
            flap_threshold: epd.health_flap_threshold,
            flap_window: epd.health_flap_window_secs.max(0.0),
            probation_secs: epd.health_probation_secs.max(0.0),
            hedge_quantile: epd.hedge_quantile.clamp(0.0, 1.0),
            hedge_min_samples: epd.hedge_min_samples.max(1),
            retry_budget_per_s: epd.retry_budget_per_s.max(0.0),
            retry_budget_burst: epd.retry_budget_burst.max(1.0),
            seed: if epd.fault_seed != 0 { epd.fault_seed } else { DEFAULT_HEALTH_SEED },
        })
    }
}

/// Breaker state of one instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: dispatch freely.
    Closed,
    /// Recently failed: skip until `open_secs` elapse or recovery lands.
    Open,
    /// Probing: admit up to the probe budget, then hold.
    HalfOpen,
    /// Flapping offender: skip until the seeded probation expires.
    Quarantined,
}

#[derive(Debug, Clone)]
struct InstanceHealth {
    state: BreakerState,
    /// Release time for Open / Quarantined (virtual or wall seconds).
    until: f64,
    /// Remaining Half-Open probe budget.
    probes_left: u32,
    /// Failure timestamps inside the flapping window (pruned lazily).
    recent_failures: Vec<f64>,
    /// Quarantine offences served — the probation-doubling exponent.
    offences: u32,
    /// Set between a failure and its recovery signal (the simulator's
    /// crash → `SwitchDone` bracket).
    pending_recovery: bool,
}

impl InstanceHealth {
    fn new() -> InstanceHealth {
        InstanceHealth {
            state: BreakerState::Closed,
            until: 0.0,
            probes_left: 0,
            recent_failures: Vec::new(),
            offences: 0,
            pending_recovery: false,
        }
    }
}

/// Health-layer event counters, merged into the shared
/// [`crate::metrics::resilience::ResilienceCounters`] by both engines.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Closed/Half-Open → Open transitions.
    pub breaker_opens: u64,
    /// Escalations into quarantine by the flapping detector.
    pub quarantines: u64,
    /// Half-Open probe admissions granted.
    pub breaker_probes: u64,
}

/// The shared per-instance health state machine.
#[derive(Debug, Clone)]
pub struct HealthTracker {
    cfg: HealthConfig,
    instances: Vec<InstanceHealth>,
    pub stats: HealthStats,
}

impl HealthTracker {
    pub fn new(cfg: HealthConfig, instances: usize) -> HealthTracker {
        HealthTracker {
            cfg,
            instances: (0..instances).map(|_| InstanceHealth::new()).collect(),
            stats: HealthStats::default(),
        }
    }

    pub fn config(&self) -> &HealthConfig {
        &self.cfg
    }

    pub fn state(&self, idx: usize) -> BreakerState {
        self.instances.get(idx).map_or(BreakerState::Closed, |h| h.state)
    }

    /// Deterministic probation for offence `k` of `instance`:
    /// `probation_secs * 2^min(k, 6)` plus seeded jitter below half the
    /// base — a pure function of `(seed, instance, k)`.
    fn probation(&self, instance: usize, offence: u32) -> f64 {
        let base = self.cfg.probation_secs;
        let scaled = base * f64::from(1u32 << offence.min(MAX_PROBATION_SHIFT));
        let jitter = Rng::new(
            self.cfg.seed
                ^ (instance as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ u64::from(offence),
        )
        .uniform(0.0, 0.5 * base.max(1e-9));
        scaled + jitter
    }

    /// Record a failure signal (sim crash/OOM, engine panic or heartbeat
    /// death) at `now`. Repeat offenders inside the flapping window land
    /// in quarantine with doubling probation; everyone else opens.
    pub fn on_failure(&mut self, now: f64, idx: usize) {
        let flap_threshold = self.cfg.flap_threshold;
        let flap_window = self.cfg.flap_window;
        let open_secs = self.cfg.open_secs;
        let Some(h) = self.instances.get_mut(idx) else { return };
        h.recent_failures.retain(|&t| now - t <= flap_window);
        h.recent_failures.push(now);
        h.probes_left = 0;
        h.pending_recovery = true;
        if flap_threshold > 0 && h.recent_failures.len() >= flap_threshold as usize {
            let offence = h.offences;
            h.state = BreakerState::Quarantined;
            h.offences += 1;
            self.stats.quarantines += 1;
            let until = now + self.probation(idx, offence);
            self.instances[idx].until = until;
        } else {
            h.state = BreakerState::Open;
            h.until = now + open_secs;
            self.stats.breaker_opens += 1;
        }
    }

    /// Record a recovery signal (the simulator's post-downtime
    /// `SwitchDone`; the engine has no in-process revival, so only the
    /// time-based release below applies there). An Open instance moves to
    /// Half-Open with a fresh probe budget; a quarantined one keeps
    /// serving its probation — that is the point of quarantine.
    pub fn on_recovery(&mut self, now: f64, idx: usize) {
        let probes = self.cfg.half_open_probes;
        let open_secs = self.cfg.open_secs;
        let Some(h) = self.instances.get_mut(idx) else { return };
        h.pending_recovery = false;
        if h.state == BreakerState::Open {
            h.state = BreakerState::HalfOpen;
            h.probes_left = probes;
            h.until = now + open_secs;
        }
    }

    /// Whether the instance's next recovery signal should be routed here
    /// (a crash is in flight between `on_failure` and `on_recovery`).
    pub fn recovery_pending(&self, idx: usize) -> bool {
        self.instances.get(idx).is_some_and(|h| h.pending_recovery)
    }

    /// Record a successfully completed work item on `idx`: a Half-Open
    /// instance that proves itself closes again.
    pub fn on_success(&mut self, _now: f64, idx: usize) {
        let Some(h) = self.instances.get_mut(idx) else { return };
        if h.state == BreakerState::HalfOpen {
            h.state = BreakerState::Closed;
            h.probes_left = 0;
        }
    }

    /// Dispatch filter: may one work item be sent to `idx` right now?
    /// Mutating — lapsed Open/Quarantined states roll into Half-Open, and
    /// a Half-Open admission consumes one probe token. Callers must treat
    /// a `false` as "prefer a sibling", never as "drop the request":
    /// when every candidate refuses, dispatch falls back to ignoring
    /// health so the breaker can degrade service but never wedge it.
    pub fn admits(&mut self, now: f64, idx: usize) -> bool {
        let probes = self.cfg.half_open_probes;
        let open_secs = self.cfg.open_secs;
        let Some(h) = self.instances.get_mut(idx) else { return true };
        match h.state {
            BreakerState::Closed => true,
            BreakerState::Open | BreakerState::Quarantined => {
                if now >= h.until && !h.pending_recovery {
                    h.state = BreakerState::HalfOpen;
                    h.probes_left = probes;
                    h.until = now + open_secs;
                    self.probe(idx)
                } else {
                    false
                }
            }
            BreakerState::HalfOpen => {
                // A spent probe budget re-arms after `open_secs`: the
                // probes may all have been dispatch *offers* that picked a
                // sibling, and with no work landing, no success signal can
                // ever close the breaker — without the re-arm the
                // instance would idle forever.
                if h.probes_left == 0 && now >= h.until {
                    h.probes_left = probes;
                    h.until = now + open_secs;
                }
                self.probe(idx)
            }
        }
    }

    fn probe(&mut self, idx: usize) -> bool {
        let h = &mut self.instances[idx];
        if h.probes_left == 0 {
            return false;
        }
        h.probes_left -= 1;
        self.stats.breaker_probes += 1;
        true
    }

    /// Non-mutating capacity view for the planner: Open and Quarantined
    /// instances contribute zero capacity; Closed and Half-Open count.
    pub fn counts_capacity(&self, now: f64, idx: usize) -> bool {
        match self.state(idx) {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open | BreakerState::Quarantined => {
                self.instances[idx].until <= now && !self.instances[idx].pending_recovery
            }
        }
    }
}

/// Cluster-wide redispatch token bucket: a crash wave may retry at most
/// `burst` items instantly and `rate` items per second sustained; past
/// that, recovery degrades to typed sheds instead of a retry storm.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryBudget {
    rate: f64,
    burst: f64,
    tokens: f64,
    last: f64,
}

impl RetryBudget {
    pub fn new(rate_per_s: f64, burst: f64) -> RetryBudget {
        let burst = burst.max(1.0);
        RetryBudget { rate: rate_per_s.max(0.0), burst, tokens: burst, last: 0.0 }
    }

    /// Take one redispatch token at `now`; `false` means the budget is
    /// exhausted and the item must shed instead of retry.
    pub fn try_take(&mut self, now: f64) -> bool {
        self.tokens = (self.tokens + (now - self.last).max(0.0) * self.rate).min(self.burst);
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// Tokens currently available (diagnostics).
    pub fn available(&self) -> f64 {
        self.tokens
    }
}

/// Per-stage hedge thresholds from streaming quantile sketches: a stage
/// wait above the configured quantile of everything previously observed
/// for that stage marks the request hedge-eligible.
#[derive(Debug, Clone)]
pub struct HedgeTracker {
    quantile: f64,
    min_samples: u64,
    sketches: Vec<QuantileSketch>,
}

impl HedgeTracker {
    /// `stages` independent sketches (the simulator indexes by work
    /// kind). 1% relative error — the same sketch the timeline-free
    /// metrics path uses.
    pub fn new(quantile: f64, min_samples: u64, stages: usize) -> HedgeTracker {
        HedgeTracker {
            quantile: quantile.clamp(0.0, 1.0),
            min_samples: min_samples.max(1),
            sketches: (0..stages).map(|_| QuantileSketch::default()).collect(),
        }
    }

    /// Record one observed stage wait.
    pub fn observe(&mut self, stage: usize, wait: f64) {
        if let Some(s) = self.sketches.get_mut(stage) {
            s.record(wait.max(0.0));
        }
    }

    /// The hedge threshold for `stage`, once enough samples exist to make
    /// the quantile meaningful; `None` while warming up (never hedge on a
    /// cold sketch).
    pub fn threshold(&self, stage: usize) -> Option<f64> {
        let s = self.sketches.get(stage)?;
        if s.count() < self.min_samples {
            return None;
        }
        Some(s.quantile(self.quantile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::topology::Topology;

    fn cfg() -> HealthConfig {
        HealthConfig {
            breaker: true,
            replan: true,
            open_secs: 5.0,
            half_open_probes: 2,
            flap_threshold: 2,
            flap_window: 60.0,
            probation_secs: 10.0,
            hedge_quantile: 0.95,
            hedge_min_samples: 4,
            retry_budget_per_s: 1.0,
            retry_budget_burst: 2.0,
            seed: 7,
        }
    }

    #[test]
    fn default_config_resolves_to_none() {
        let epd = EpdConfig::epd(Topology::new(1, 1, 1), 1, 1, 128);
        assert!(HealthConfig::from_epd(&epd).is_none(), "health layer must default dormant");
        let mut on = epd;
        on.health_breaker = true;
        assert!(HealthConfig::from_epd(&on).is_some());
    }

    #[test]
    fn breaker_cycle_closed_open_halfopen_closed() {
        let mut t = HealthTracker::new(cfg(), 2);
        assert!(t.admits(0.0, 0));
        t.on_failure(1.0, 0);
        assert_eq!(t.state(0), BreakerState::Open);
        assert!(!t.admits(2.0, 0), "open instances are skipped");
        assert!(t.admits(2.0, 1), "siblings unaffected");
        t.on_recovery(3.0, 0);
        assert_eq!(t.state(0), BreakerState::HalfOpen);
        // Bounded probing: exactly `half_open_probes` admissions.
        assert!(t.admits(3.0, 0));
        assert!(t.admits(3.0, 0));
        assert!(!t.admits(3.0, 0), "probe budget exhausted");
        t.on_success(4.0, 0);
        assert_eq!(t.state(0), BreakerState::Closed);
        assert!(t.admits(5.0, 0));
        assert_eq!(t.stats.breaker_opens, 1);
        assert_eq!(t.stats.breaker_probes, 2);
        assert_eq!(t.stats.quarantines, 0);
    }

    #[test]
    fn open_lapses_into_half_open_without_recovery_signal() {
        // The engine path: no revival event, the time-based release must
        // re-probe after `open_secs` — but only once the failure's
        // recovery bracket is not pending (sim crashes must wait for
        // their SwitchDone).
        let mut t = HealthTracker::new(cfg(), 1);
        t.on_failure(0.0, 0);
        assert!(!t.admits(10.0, 0), "pending recovery holds the breaker");
        t.on_recovery(0.5, 0);
        t.on_failure(100.0, 0); // outside the flap window: opens again
        t.on_recovery(100.5, 0);
        t.on_success(101.0, 0);
        t.on_failure(200.0, 0);
        t.instances[0].pending_recovery = false; // engine-style: no bracket
        assert!(!t.admits(204.9, 0), "still inside open_secs");
        assert!(t.admits(205.1, 0), "lapsed open rolls into a probe");
        assert_eq!(t.state(0), BreakerState::HalfOpen);
    }

    #[test]
    fn spent_probe_budget_rearms_after_open_secs() {
        // All probes can be consumed as dispatch *offers* that end up
        // picking a sibling; the breaker must re-offer the instance after
        // another `open_secs` instead of idling it forever.
        let mut t = HealthTracker::new(cfg(), 1);
        t.on_failure(0.0, 0);
        t.on_recovery(1.0, 0);
        assert!(t.admits(1.0, 0));
        assert!(t.admits(1.0, 0));
        assert!(!t.admits(1.0, 0), "budget spent");
        assert!(!t.admits(5.9, 0), "still inside the re-arm window");
        assert!(t.admits(6.1, 0), "budget re-arms after open_secs");
        assert_eq!(t.state(0), BreakerState::HalfOpen);
    }

    #[test]
    fn flapping_escalates_to_quarantine_with_doubling_probation() {
        let mut t = HealthTracker::new(cfg(), 1);
        t.on_failure(0.0, 0);
        t.on_recovery(1.0, 0);
        t.on_failure(2.0, 0); // 2nd failure inside the 60 s window
        assert_eq!(t.state(0), BreakerState::Quarantined);
        assert_eq!(t.stats.quarantines, 1);
        let first_until = t.instances[0].until;
        assert!(first_until >= 2.0 + 10.0, "probation at least the base");
        assert!(first_until <= 2.0 + 10.0 + 5.0, "jitter below half the base");
        // Recovery does not release quarantine.
        t.on_recovery(3.0, 0);
        assert_eq!(t.state(0), BreakerState::Quarantined);
        assert!(!t.admits(first_until - 0.1, 0));
        // Probation expiry releases into a bounded probe.
        assert!(t.admits(first_until + 0.1, 0));
        assert_eq!(t.state(0), BreakerState::HalfOpen);
        t.on_success(first_until + 0.2, 0);
        // A third offence doubles the probation.
        t.on_failure(first_until + 1.0, 0);
        assert_eq!(t.state(0), BreakerState::Quarantined);
        let second = t.instances[0].until - (first_until + 1.0);
        assert!(second >= 20.0, "offence 1 serves 2x the base: {second}");
    }

    #[test]
    fn probation_is_deterministic_in_seed_instance_offence() {
        let t = HealthTracker::new(cfg(), 3);
        assert_eq!(t.probation(1, 0).to_bits(), t.probation(1, 0).to_bits());
        assert_ne!(t.probation(1, 0).to_bits(), t.probation(2, 0).to_bits());
        assert_ne!(t.probation(1, 0).to_bits(), t.probation(1, 1).to_bits());
    }

    #[test]
    fn planner_capacity_view_is_non_mutating() {
        let mut t = HealthTracker::new(cfg(), 2);
        t.on_failure(0.0, 0);
        assert!(!t.counts_capacity(1.0, 0), "open = zero capacity");
        assert!(t.counts_capacity(1.0, 1));
        t.on_recovery(2.0, 0);
        assert!(t.counts_capacity(2.5, 0), "half-open counts as capacity");
        let probes_before = t.instances[0].probes_left;
        let _ = t.counts_capacity(2.5, 0);
        assert_eq!(t.instances[0].probes_left, probes_before, "view consumes nothing");
    }

    #[test]
    fn retry_budget_caps_burst_and_refills() {
        let mut b = RetryBudget::new(1.0, 2.0);
        assert!(b.try_take(0.0));
        assert!(b.try_take(0.0));
        assert!(!b.try_take(0.0), "burst spent");
        assert!(!b.try_take(0.5), "half a token is not a token");
        assert!(b.try_take(1.5), "refilled at 1/s");
        assert!((b.available() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn hedge_threshold_needs_warmup_then_tracks_quantile() {
        let mut h = HedgeTracker::new(0.9, 4, 2);
        h.observe(0, 1.0);
        h.observe(0, 1.0);
        h.observe(0, 1.0);
        assert_eq!(h.threshold(0), None, "cold sketch never hedges");
        h.observe(0, 10.0);
        let th = h.threshold(0).expect("warm sketch");
        assert!(th > 5.0, "p90 of [1,1,1,10] sits at the tail: {th}");
        assert_eq!(h.threshold(1), None, "stages are independent");
    }
}
