//! SLO-aware admission control: project TTFT/TPOT for an arriving
//! request from live backlogs plus profiled service EWMAs, then admit,
//! degrade, or shed.
//!
//! The projection (documented in ARCHITECTURE.md "Front door &
//! admission"):
//!
//! ```text
//! TTFT ≈ entry_wait + encode_cost + prefill_wait + prefill_cost
//! TPOT ≈ decode_step                (profiled per-token service EWMA)
//! ```
//!
//! Text-only requests carry `entry_wait = encode_cost = 0` on the EPD
//! path — the encoder bypass, quantified. Both the simulator and the
//! real engine build an [`AdmissionOutlook`] from their own measured
//! state and share [`decide`], so the policy cannot drift between them.

use crate::core::request::Priority;

use super::RouterConfig;

/// Projected-overload ratio up to which a request is degraded (capped
/// tokens, batch class) rather than shed, when degrading is enabled.
pub const DEGRADE_OVER: f64 = 2.0;

/// Inputs to the admission projection, in seconds. Queue waits are
/// amortized per live instance of the relevant stage.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdmissionOutlook {
    /// Queued work ahead of the request at its entry stage (encode for
    /// multimodal requests; 0 for text-only on the EPD path).
    pub entry_wait: f64,
    /// The request's own encode cost (0 for text-only).
    pub encode_cost: f64,
    /// Queued prefill-side work the request will wait behind.
    pub prefill_wait: f64,
    /// The request's own prefill cost.
    pub prefill_cost: f64,
    /// Profiled per-output-token decode service time.
    pub decode_step: f64,
}

impl AdmissionOutlook {
    /// The TTFT projection: every queue the request waits in, plus its
    /// own pre-first-token service.
    pub fn projected_ttft(&self) -> f64 {
        self.entry_wait + self.encode_cost + self.prefill_wait + self.prefill_cost
    }

    /// The TPOT projection.
    pub fn projected_tpot(&self) -> f64 {
        self.decode_step
    }
}

/// What the front door does with an arriving request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionDecision {
    Admit,
    /// Serve degraded: cap generation at `max_tokens` and drop to the
    /// batch class, relieving decode pressure instead of refusing.
    Degrade { max_tokens: u32 },
    /// Refuse (HTTP 429 in the engine, `rejected` in the sim), with a
    /// backoff hint derived from the projected excess.
    Shed { retry_after_ms: u64 },
}

/// The stateless decision kernel shared by sim and engine.
///
/// `ttft_budget` is the request's own remaining deadline slack
/// (`INFINITY` when it carries none); the effective TTFT bound is the
/// tighter of the SLO target (scaled by headroom) and that budget.
pub fn decide(
    cfg: &RouterConfig,
    outlook: &AdmissionOutlook,
    class: Priority,
    ttft_budget: f64,
) -> AdmissionDecision {
    let ttft = outlook.projected_ttft();
    let tpot = outlook.projected_tpot();
    let ttft_bound = (cfg.slo.ttft * cfg.headroom).min(ttft_budget);
    let tpot_bound = cfg.slo.tpot * cfg.headroom;
    if ttft <= ttft_bound && tpot <= tpot_bound {
        return AdmissionDecision::Admit;
    }
    let over = (ttft / ttft_bound).max(tpot / tpot_bound);
    if cfg.degrade && class == Priority::Interactive && over <= DEGRADE_OVER {
        return AdmissionDecision::Degrade { max_tokens: cfg.degrade_tokens };
    }
    let excess_ms = ((ttft - ttft_bound).max(0.0) * 1000.0) as u64;
    // Backoff hint: the projected drain time of the queues ahead — the
    // only component of the projection that improves by waiting (the
    // request's own encode/prefill costs do not shrink). The SLO excess
    // keeps the hint proportional under heavy overload, and the
    // configured floor backstops an all-service-time projection.
    let drain_ms = ((outlook.entry_wait + outlook.prefill_wait) * 1000.0) as u64;
    AdmissionDecision::Shed {
        retry_after_ms: excess_ms.max(drain_ms).max(cfg.retry_after_ms),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::slo::Slo;

    fn cfg(ttft: f64, tpot: f64, degrade: bool) -> RouterConfig {
        RouterConfig {
            slo: Slo::new(ttft, tpot),
            headroom: 1.0,
            depth: 4,
            degrade,
            degrade_tokens: 8,
            retry_after_ms: 250,
            default_weight: 1,
            weights: vec![],
        }
    }

    fn outlook(ttft: f64, tpot: f64) -> AdmissionOutlook {
        AdmissionOutlook { prefill_cost: ttft, decode_step: tpot, ..Default::default() }
    }

    #[test]
    fn infinite_targets_always_admit() {
        let c = cfg(f64::INFINITY, f64::INFINITY, false);
        let d = decide(&c, &outlook(1e9, 1e9), Priority::Interactive, f64::INFINITY);
        assert_eq!(d, AdmissionDecision::Admit);
    }

    #[test]
    fn within_slo_admits() {
        let c = cfg(2.0, 0.05, true);
        let d = decide(&c, &outlook(1.5, 0.04), Priority::Interactive, f64::INFINITY);
        assert_eq!(d, AdmissionDecision::Admit);
    }

    #[test]
    fn mild_overload_degrades_interactive() {
        let c = cfg(2.0, 0.05, true);
        let d = decide(&c, &outlook(3.0, 0.04), Priority::Interactive, f64::INFINITY);
        assert_eq!(d, AdmissionDecision::Degrade { max_tokens: 8 });
    }

    #[test]
    fn batch_and_heavy_overload_shed() {
        let c = cfg(2.0, 0.05, true);
        // Batch never degrades — it is already the degraded class.
        match decide(&c, &outlook(3.0, 0.04), Priority::Batch, f64::INFINITY) {
            AdmissionDecision::Shed { retry_after_ms } => assert!(retry_after_ms >= 250),
            other => panic!("expected shed, got {other:?}"),
        }
        // Heavy overload sheds even interactive, with a proportional hint.
        match decide(&c, &outlook(10.0, 0.04), Priority::Interactive, f64::INFINITY) {
            AdmissionDecision::Shed { retry_after_ms } => assert_eq!(retry_after_ms, 8000),
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn degrade_off_sheds_instead() {
        let c = cfg(2.0, 0.05, false);
        match decide(&c, &outlook(3.0, 0.04), Priority::Interactive, f64::INFINITY) {
            AdmissionDecision::Shed { .. } => {}
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn deadline_tightens_the_bound() {
        let c = cfg(f64::INFINITY, f64::INFINITY, false);
        // No SLO target, but the request's own deadline budget gates it.
        match decide(&c, &outlook(2.0, 0.0), Priority::Interactive, 1.0) {
            AdmissionDecision::Shed { retry_after_ms } => assert_eq!(retry_after_ms, 1000),
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn shed_hint_tracks_queue_drain_time() {
        let c = cfg(2.0, 0.05, false);
        // Overload driven by queued work: the hint is the projected
        // drain of the queues ahead (3.0 s + 1.5 s), not the 250 ms
        // static floor — by the hinted retry, the backlog has cleared.
        let o = AdmissionOutlook {
            entry_wait: 3.0,
            prefill_wait: 1.5,
            prefill_cost: 0.1,
            decode_step: 0.01,
            ..Default::default()
        };
        match decide(&c, &o, Priority::Interactive, f64::INFINITY) {
            AdmissionDecision::Shed { retry_after_ms } => assert_eq!(retry_after_ms, 4500),
            other => panic!("expected shed, got {other:?}"),
        }
        // Overload from pure service time still falls back to the floor.
        let o2 = AdmissionOutlook { prefill_cost: 2.1, ..Default::default() };
        match decide(&c, &o2, Priority::Interactive, f64::INFINITY) {
            AdmissionDecision::Shed { retry_after_ms } => assert_eq!(retry_after_ms, 250),
            other => panic!("expected shed, got {other:?}"),
        }
    }

    #[test]
    fn tpot_overload_sheds() {
        let c = cfg(10.0, 0.02, false);
        match decide(&c, &outlook(0.5, 0.09), Priority::Interactive, f64::INFINITY) {
            AdmissionDecision::Shed { retry_after_ms } => assert_eq!(retry_after_ms, 250),
            other => panic!("expected shed, got {other:?}"),
        }
    }
}
