//! Service-level objectives: the TTFT/TPOT thresholds from Appendix E.3
//! (Table 9) and the dataset-specific criteria used in §4.1.

use crate::model::spec::ModelId;

/// A TTFT/TPOT SLO pair, seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    pub ttft: f64,
    pub tpot: f64,
}

impl Slo {
    pub const fn new(ttft: f64, tpot: f64) -> Slo {
        Slo { ttft, tpot }
    }

    /// Does a request with the given measured latencies attain the SLO?
    pub fn attained(&self, ttft: f64, tpot: f64) -> bool {
        ttft <= self.ttft && tpot <= self.tpot
    }
}

/// Lookup of the paper's SLO criteria.
pub struct SloTable;

impl SloTable {
    /// Table 9: per-model SLOs by images-per-request for the synthetic
    /// workload. (The 6-image InternVL-26B TPOT of 0.95 in the paper is a
    /// typo for 0.095; we keep the published value for fidelity and note it
    /// in EXPERIMENTS.md.)
    pub fn synthetic(model: ModelId, images_per_request: u32) -> Option<Slo> {
        let table: &[(u32, Slo, Slo, Slo)] = &[
            // (#I/R, MiniCPM, InternVL-8B, InternVL-26B)
            (2, Slo::new(1.40, 0.04), Slo::new(1.20, 0.05), Slo::new(3.50, 0.07)),
            (4, Slo::new(2.60, 0.04), Slo::new(2.40, 0.06), Slo::new(7.05, 0.08)),
            (6, Slo::new(3.90, 0.06), Slo::new(3.55, 0.09), Slo::new(11.00, 0.95)),
            (8, Slo::new(5.10, 0.06), Slo::new(5.00, 0.18), Slo::new(15.00, 0.15)),
        ];
        let row = table.iter().find(|(n, ..)| *n == images_per_request)?;
        match model {
            ModelId::MiniCpmV26 => Some(row.1),
            ModelId::InternVl2_8b => Some(row.2),
            ModelId::InternVl2_26b => Some(row.3),
            _ => None,
        }
    }

    /// NextQA experiment (§4.1): TTFT = 5.60 s, TPOT = 0.06 s.
    pub fn nextqa() -> Slo {
        Slo::new(5.60, 0.06)
    }

    /// Video-MME experiment (§4.1): TTFT ≤ 3.1 s, TPOT ≤ 0.025 s.
    pub fn videomme() -> Slo {
        Slo::new(3.1, 0.025)
    }

    /// Audio experiment (App. A.1): TTFT ≤ 2.0 s, TPOT ≤ 0.025 s.
    pub fn audio() -> Slo {
        Slo::new(2.0, 0.025)
    }

    /// NPU experiment (§4.5): TTFT ≤ 8.5 s, TPOT ≤ 0.12 s.
    pub fn npu() -> Slo {
        Slo::new(8.5, 0.12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table9_lookups() {
        let s = SloTable::synthetic(ModelId::MiniCpmV26, 2).unwrap();
        assert_eq!(s, Slo::new(1.40, 0.04));
        let s = SloTable::synthetic(ModelId::InternVl2_26b, 8).unwrap();
        assert_eq!(s, Slo::new(15.00, 0.15));
        assert!(SloTable::synthetic(ModelId::MiniCpmV26, 3).is_none());
        assert!(SloTable::synthetic(ModelId::TinyLmm, 2).is_none());
    }

    #[test]
    fn attainment_boundary() {
        let s = Slo::new(1.0, 0.05);
        assert!(s.attained(1.0, 0.05));
        assert!(!s.attained(1.01, 0.05));
        assert!(!s.attained(1.0, 0.051));
    }

    #[test]
    fn dataset_slos() {
        assert_eq!(SloTable::nextqa().ttft, 5.60);
        assert_eq!(SloTable::videomme().tpot, 0.025);
        assert_eq!(SloTable::npu().ttft, 8.5);
    }
}
