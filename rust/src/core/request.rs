//! The multimodal request and its lifecycle timeline.

use crate::model::vision::Resolution;

/// Unique request identifier.
pub type RequestId = u64;

/// Where a request currently is in the E→P→D pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestPhase {
    /// Waiting in (or being assigned to) an encode queue.
    PendingEncode,
    Encoding,
    /// MM tokens produced; EP-migration pending/in-flight.
    MigratingToPrefill,
    PendingPrefill,
    Prefilling,
    /// KV cache produced; PD-migration pending/in-flight.
    MigratingToDecode,
    PendingDecode,
    Decoding,
    Finished,
}

/// Priority class carried by every request and consumed by the
/// front-door router (`router = "on"`): interactive traffic drains
/// before batch at every fair-queue band.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Priority {
    /// Latency-sensitive traffic, served first.
    #[default]
    Interactive,
    /// Throughput traffic, drained only when no interactive work waits.
    Batch,
}

impl Priority {
    pub fn parse(s: &str) -> Option<Priority> {
        match s {
            "interactive" => Some(Priority::Interactive),
            "batch" => Some(Priority::Batch),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Priority::Interactive => "interactive",
            Priority::Batch => "batch",
        }
    }

    /// Band index used by per-class queues (interactive drains first).
    pub fn band(self) -> usize {
        match self {
            Priority::Interactive => 0,
            Priority::Batch => 1,
        }
    }
}

/// A serving request: prompt + multimodal payload + generation length.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Arrival time, seconds since experiment start.
    pub arrival: f64,
    /// Text prompt length in tokens.
    pub prompt_tokens: u32,
    /// Number of images (or audio clips / video frames) attached.
    pub images: u32,
    /// Resolution of each image.
    pub resolution: Resolution,
    /// Number of output tokens to generate.
    pub output_tokens: u32,
    /// Precomputed tiles per image for the target model (cached by the
    /// workload generator so the hot path never recomputes tiling).
    pub tiles_per_image: u32,
    /// Precomputed MM tokens per image.
    pub mm_tokens_per_image: u32,
    /// Content address of the attached media, computed at admission
    /// (FNV-1a over the media bytes — see [`crate::cache::content_hash`]).
    /// `Some` enables the cross-request encoder cache: requests sharing a
    /// hash share encoder output. `None` (the default for workloads
    /// without repeated media) opts the request out of caching.
    pub media_hash: Option<u64>,
    /// Tenant id for per-tenant weighted fairness at the front door
    /// (0 = the default tenant; inert while `router = "off"`).
    pub tenant: u32,
    /// Priority class; `Interactive` everywhere the router is off.
    pub class: Priority,
    /// Absolute first-token deadline, seconds since experiment start
    /// (`f64::INFINITY` = none). Consumed by SLO-aware queueing and the
    /// router's admission projection.
    pub deadline: f64,
}

impl Request {
    /// Total encoder tiles in this request.
    pub fn total_tiles(&self) -> u32 {
        self.images * self.tiles_per_image
    }

    /// Total multimodal tokens this request contributes to prefill.
    pub fn total_mm_tokens(&self) -> u64 {
        self.images as u64 * self.mm_tokens_per_image as u64
    }

    /// Total prefill context length (MM + text prompt).
    pub fn prefill_tokens(&self) -> u64 {
        self.total_mm_tokens() + self.prompt_tokens as u64
    }

    /// Final sequence length after generation completes.
    pub fn final_tokens(&self) -> u64 {
        self.prefill_tokens() + self.output_tokens as u64
    }
}

/// Timestamps collected as a request moves through the pipeline.
/// All in seconds since experiment start; `f64::NAN` until set.
#[derive(Debug, Clone)]
pub struct RequestTimeline {
    pub id: RequestId,
    pub arrival: f64,
    pub encode_start: f64,
    pub encode_end: f64,
    pub prefill_start: f64,
    pub prefill_end: f64,
    /// Time the first output token reached the user (end of prefill plus
    /// any PD-migration the first token waits on).
    pub first_token: f64,
    pub finish: f64,
    pub output_tokens: u32,
}

impl RequestTimeline {
    pub fn new(id: RequestId, arrival: f64) -> RequestTimeline {
        RequestTimeline {
            id,
            arrival,
            encode_start: f64::NAN,
            encode_end: f64::NAN,
            prefill_start: f64::NAN,
            prefill_end: f64::NAN,
            first_token: f64::NAN,
            finish: f64::NAN,
            output_tokens: 0,
        }
    }

    /// Time to first token (§4's TTFT).
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Time per output token excluding the first (§4's TPOT). Zero when
    /// one or fewer tokens were generated.
    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            return 0.0;
        }
        (self.finish - self.first_token) / (self.output_tokens - 1) as f64
    }

    /// End-to-end latency.
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    pub fn is_finished(&self) -> bool {
        !self.finish.is_nan()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request {
            id: 1,
            arrival: 0.0,
            prompt_tokens: 22,
            images: 4,
            resolution: Resolution::four_k(),
            output_tokens: 10,
            tiles_per_image: 10,
            mm_tokens_per_image: 640,
            media_hash: None,
            tenant: 0,
            class: Priority::Interactive,
            deadline: f64::INFINITY,
        }
    }

    #[test]
    fn priority_parse_roundtrip() {
        for p in [Priority::Interactive, Priority::Batch] {
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::parse("urgent"), None);
        assert!(Priority::Interactive.band() < Priority::Batch.band());
        assert_eq!(Priority::default(), Priority::Interactive);
    }

    #[test]
    fn token_arithmetic() {
        let r = req();
        assert_eq!(r.total_tiles(), 40);
        assert_eq!(r.total_mm_tokens(), 2560);
        assert_eq!(r.prefill_tokens(), 2582);
        assert_eq!(r.final_tokens(), 2592);
    }

    #[test]
    fn timeline_metrics() {
        let mut t = RequestTimeline::new(1, 10.0);
        t.first_token = 12.5;
        t.finish = 13.4;
        t.output_tokens = 10;
        assert!((t.ttft() - 2.5).abs() < 1e-12);
        assert!((t.tpot() - 0.1).abs() < 1e-12);
        assert!((t.latency() - 3.4).abs() < 1e-12);
        assert!(t.is_finished());
    }

    #[test]
    fn tpot_degenerate_single_token() {
        let mut t = RequestTimeline::new(1, 0.0);
        t.first_token = 1.0;
        t.finish = 1.0;
        t.output_tokens = 1;
        assert_eq!(t.tpot(), 0.0);
    }
}
