//! Deployment topologies: how stages map onto instances/GPUs.
//!
//! The paper's three compared systems are three topologies of the same
//! pipeline:
//! - **EPD** (ours): dedicated E, P and D instances ("5E2P1D").
//! - **PD / DistServe**: encode+prefill colocated, decode separate ("7P1D"
//!   where each P instance runs E then P).
//! - **Aggregated / vLLM**: every instance runs all three stages.

use super::stage::Stage;

/// Which system architecture a set of instances implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeploymentMode {
    /// Full EPD disaggregation (the paper's contribution).
    Epd,
    /// Prefill–decode disaggregation with encode fused into prefill
    /// (the extended-DistServe baseline).
    PdDisagg,
    /// Monolithic: all stages on every instance (the vLLM baseline).
    Aggregated,
}

impl DeploymentMode {
    pub fn parse(s: &str) -> Option<DeploymentMode> {
        match s.to_ascii_lowercase().as_str() {
            "epd" => Some(DeploymentMode::Epd),
            "pd" | "distserve" | "pd-disagg" => Some(DeploymentMode::PdDisagg),
            "aggregated" | "vllm" | "agg" => Some(DeploymentMode::Aggregated),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DeploymentMode::Epd => "EPD",
            DeploymentMode::PdDisagg => "DistServe",
            DeploymentMode::Aggregated => "vLLM",
        }
    }

    /// The stages an instance assigned `role` actually executes under this
    /// mode. In PD mode a "prefill" instance also encodes; in aggregated
    /// mode every instance does everything.
    pub fn stages_for_role(&self, role: Stage) -> &'static [Stage] {
        match self {
            DeploymentMode::Epd => match role {
                Stage::Encode => &[Stage::Encode],
                Stage::Prefill => &[Stage::Prefill],
                Stage::Decode => &[Stage::Decode],
            },
            DeploymentMode::PdDisagg => match role {
                Stage::Encode | Stage::Prefill => &[Stage::Encode, Stage::Prefill],
                Stage::Decode => &[Stage::Decode],
            },
            DeploymentMode::Aggregated => &[Stage::Encode, Stage::Prefill, Stage::Decode],
        }
    }
}

/// A cluster topology: per-stage instance counts, e.g. "5E2P1D".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Topology {
    pub encode: u32,
    pub prefill: u32,
    pub decode: u32,
}

impl Topology {
    pub const fn new(encode: u32, prefill: u32, decode: u32) -> Topology {
        Topology { encode, prefill, decode }
    }

    /// Parse a "5E2P1D"-style string (stage letters may appear in any
    /// order; missing stages default to zero).
    pub fn parse(s: &str) -> Option<Topology> {
        let mut t = Topology::new(0, 0, 0);
        let mut num = String::new();
        let mut saw_any = false;
        for c in s.chars() {
            if c.is_ascii_digit() {
                num.push(c);
            } else {
                let stage = Stage::from_code(c)?;
                let n: u32 = num.parse().ok()?;
                num.clear();
                saw_any = true;
                match stage {
                    Stage::Encode => t.encode += n,
                    Stage::Prefill => t.prefill += n,
                    Stage::Decode => t.decode += n,
                }
            }
        }
        if !num.is_empty() || !saw_any {
            return None;
        }
        Some(t)
    }

    pub fn total(&self) -> u32 {
        self.encode + self.prefill + self.decode
    }

    pub fn count(&self, stage: Stage) -> u32 {
        match stage {
            Stage::Encode => self.encode,
            Stage::Prefill => self.prefill,
            Stage::Decode => self.decode,
        }
    }

    pub fn set_count(&mut self, stage: Stage, n: u32) {
        match stage {
            Stage::Encode => self.encode = n,
            Stage::Prefill => self.prefill = n,
            Stage::Decode => self.decode = n,
        }
    }

    /// Expand into per-instance roles, encode instances first.
    pub fn roles(&self) -> Vec<Stage> {
        let mut v = Vec::with_capacity(self.total() as usize);
        v.extend(std::iter::repeat(Stage::Encode).take(self.encode as usize));
        v.extend(std::iter::repeat(Stage::Prefill).take(self.prefill as usize));
        v.extend(std::iter::repeat(Stage::Decode).take(self.decode as usize));
        v
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}E{}P{}D", self.encode, self.prefill, self.decode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display() {
        let t = Topology::parse("5E2P1D").unwrap();
        assert_eq!(t, Topology::new(5, 2, 1));
        assert_eq!(t.to_string(), "5E2P1D");
        assert_eq!(Topology::parse("7P1D"), Some(Topology::new(0, 7, 1)));
        assert_eq!(Topology::parse("2e1p1d"), Some(Topology::new(2, 1, 1)));
        assert_eq!(Topology::parse(""), None);
        assert_eq!(Topology::parse("5X"), None);
        assert_eq!(Topology::parse("5"), None);
    }

    #[test]
    fn totals_and_roles() {
        let t = Topology::new(2, 1, 1);
        assert_eq!(t.total(), 4);
        assert_eq!(
            t.roles(),
            vec![Stage::Encode, Stage::Encode, Stage::Prefill, Stage::Decode]
        );
    }

    #[test]
    fn mode_stage_expansion() {
        assert_eq!(
            DeploymentMode::PdDisagg.stages_for_role(Stage::Prefill),
            &[Stage::Encode, Stage::Prefill]
        );
        assert_eq!(
            DeploymentMode::Epd.stages_for_role(Stage::Prefill),
            &[Stage::Prefill]
        );
        assert_eq!(DeploymentMode::Aggregated.stages_for_role(Stage::Decode).len(), 3);
    }

    #[test]
    fn mode_parse() {
        assert_eq!(DeploymentMode::parse("vllm"), Some(DeploymentMode::Aggregated));
        assert_eq!(DeploymentMode::parse("distserve"), Some(DeploymentMode::PdDisagg));
        assert_eq!(DeploymentMode::parse("epd"), Some(DeploymentMode::Epd));
        assert_eq!(DeploymentMode::parse("zzz"), None);
    }
}
