//! Core request/stage/topology/SLO types shared by the simulator, the real
//! engine and the optimizer.

pub mod request;
pub mod stage;
pub mod topology;
pub mod slo;
pub mod config;

pub use config::{EpdConfig, InstanceConfig, PlannerPolicy, SchedulingConfig};
pub use request::{Request, RequestId, RequestPhase, RequestTimeline};
pub use slo::{Slo, SloTable};
pub use stage::Stage;
pub use topology::{DeploymentMode, Topology};
