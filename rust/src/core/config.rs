//! System configuration: the (p, b, s) tuple of Appendix D — per-instance
//! parallelization, max batch sizes and scheduling strategies — plus the
//! feature toggles the ablations flip (IRP, role switching).

use super::stage::Stage;
use super::topology::{DeploymentMode, Topology};
use crate::util::toml::TomlDoc;

/// Queue-ordering strategy within an instance (Appendix D "Scheduling").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QueuePolicy {
    /// First-come-first-served (the paper's default, §E.1).
    Fcfs,
    /// Shortest-job-first by estimated stage cost.
    Sjf,
    /// Earliest-SLO-deadline-first.
    SloAware,
    /// Class-band order (interactive before batch), FCFS within a band —
    /// the per-instance companion of the front-door priority queues.
    Priority,
}

impl QueuePolicy {
    pub fn parse(s: &str) -> Option<QueuePolicy> {
        match s.to_ascii_lowercase().as_str() {
            "fcfs" => Some(QueuePolicy::Fcfs),
            "sjf" => Some(QueuePolicy::Sjf),
            "slo" | "slo-aware" => Some(QueuePolicy::SloAware),
            "priority" => Some(QueuePolicy::Priority),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            QueuePolicy::Fcfs => "fcfs",
            QueuePolicy::Sjf => "sjf",
            QueuePolicy::SloAware => "slo-aware",
            QueuePolicy::Priority => "priority",
        }
    }
}

/// Whether the SLO-aware multi-path front door (`router/`) fronts the
/// submit path. `Off` (the default) keeps the legacy single path
/// bit-for-bit: no fair queues, no admission projection, no shedding.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RouterPolicy {
    Off,
    On,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "off" => Some(RouterPolicy::Off),
            "on" => Some(RouterPolicy::On),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::Off => "off",
            RouterPolicy::On => "on",
        }
    }
}

/// Instance-assignment strategy at stage entry (Appendix D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AssignPolicy {
    RoundRobin,
    LeastLoaded,
}

impl AssignPolicy {
    pub fn parse(s: &str) -> Option<AssignPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" => Some(AssignPolicy::RoundRobin),
            "ll" | "least-loaded" => Some(AssignPolicy::LeastLoaded),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            AssignPolicy::RoundRobin => "round-robin",
            AssignPolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// Which reallocation policy drives role switching when
/// `EpdConfig::role_switching` is on (§3.2.3 + §3.2.4 unified).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlannerPolicy {
    /// The legacy one-instance-at-a-time `RoleSwitchController`
    /// heuristic — bit-for-bit with pre-planner behavior.
    Greedy,
    /// The online reallocation planner: scores topology neighborhoods
    /// against the profiled workload and emits multi-step switch plans.
    Predictive,
    /// The predictive planner with two-tier candidate evaluation: an
    /// online GP surrogate EI-ranks the whole neighborhood, and only the
    /// top-k (plus high-uncertainty explorations) get an honest
    /// short-horizon what-if simulation. See
    /// `optimizer::{surrogate, whatif}`.
    Surrogate,
}

impl PlannerPolicy {
    pub fn parse(s: &str) -> Option<PlannerPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Some(PlannerPolicy::Greedy),
            "predictive" | "planner" => Some(PlannerPolicy::Predictive),
            "surrogate" => Some(PlannerPolicy::Surrogate),
            _ => None,
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            PlannerPolicy::Greedy => "greedy",
            PlannerPolicy::Predictive => "predictive",
            PlannerPolicy::Surrogate => "surrogate",
        }
    }
}

/// Per-stage scheduling configuration (all instances within a stage share
/// one strategy, as Appendix D constrains).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SchedulingConfig {
    pub queue: QueuePolicy,
    pub assign: AssignPolicy,
}

impl Default for SchedulingConfig {
    fn default() -> Self {
        SchedulingConfig {
            queue: QueuePolicy::Fcfs,
            assign: AssignPolicy::LeastLoaded,
        }
    }
}

/// Per-instance configuration (one element of the paper's p and b vectors).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct InstanceConfig {
    pub role: Stage,
    /// Max concurrent requests batched per step.
    pub max_batch: u32,
    /// Tensor-parallel degree (GPUs per instance). For encode instances
    /// this is the IRP fan-out (Appendix D overloads p^TP = p^IRP).
    pub tp: u32,
    /// Pipeline-parallel degree.
    pub pp: u32,
}

impl InstanceConfig {
    pub fn new(role: Stage, max_batch: u32) -> InstanceConfig {
        InstanceConfig { role, max_batch, tp: 1, pp: 1 }
    }

    /// GPUs consumed by this instance.
    pub fn gpus(&self) -> u32 {
        self.tp * self.pp
    }
}

/// Complete system configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct EpdConfig {
    pub mode: DeploymentMode,
    pub instances: Vec<InstanceConfig>,
    pub sched_encode: SchedulingConfig,
    pub sched_prefill: SchedulingConfig,
    pub sched_decode: SchedulingConfig,
    /// Intra-request parallelism across encode instances (§3.2.2).
    pub irp: bool,
    /// Dynamic role switching (§3.2.4).
    pub role_switching: bool,
    /// Fraction of post-weight free memory reserved for KV cache (§E.1
    /// uses 50% in latency experiments, 80% in capacity experiments).
    pub kv_frac: f64,
    /// MM cache entries per instance (§E.1 fixes 3000).
    pub mm_cache_entries: u32,
    /// Capacity of the cluster-wide, cross-request encoder cache in MM
    /// tokens (content-addressed LRU over encoder outputs; see
    /// `cache::encoder_cache`). 0 disables it. Only requests whose
    /// workload assigns a `media_hash` participate, so enabling it leaves
    /// unique-media workloads bit-identical.
    pub encoder_cache_tokens: u64,
    /// Chunk size in MM tokens for the streamed encode→prefill handoff:
    /// encoded tokens are transferred and admitted to the prefill queue
    /// as they complete, so prefill computes over the prompt prefix and
    /// early media chunks while later shards are still encoding
    /// (RServe-style EP overlap). IRP shard boundaries are aligned to
    /// chunk boundaries so intra-request parallelism composes with
    /// streaming. The simulator models intra-shard emission at exactly
    /// this granularity, including partial prefill passes over streamed
    /// prefixes. The real engine streams the *transfer* at shard
    /// granularity — each shard (sized to a whole number of chunks by the
    /// aligned plan) is emitted as one partial payload the moment it
    /// encodes and reassembled at the prefill side — but its prefill
    /// compute still starts once reassembly completes (the tiny runtime
    /// has no incremental prefill), so with IRP disabled the engine
    /// handoff stays effectively monolithic. 0 (the default) keeps the
    /// paper's all-at-once handoff.
    pub ep_chunk_tokens: u64,
    /// Layer groups for the streamed prefill→decode KV handoff (the
    /// §3.2.1 disaggregated-transfer mechanism applied to the PD edge,
    /// Mooncake-style): when > 0 the decode target is selected at
    /// *prefill start* — not at transfer completion — and each layer
    /// group's KV streams to it as soon as its layers finish computing,
    /// so only the tail group's transfer (plus link latency) remains on
    /// the critical path after prefill, and the request joins the
    /// pre-reserving decoder's continuous batch the moment the tail
    /// group lands. The simulator models group emission across each
    /// prefill pass with early KV-block reservation and a re-target
    /// path for mid-stream role switches; the real engine splits the
    /// prefilled KV into contiguous groups that transfer as individual
    /// `Job::KvChunk`s and reassemble byte-identically at the decode
    /// side. 0 (the default) keeps the paper's monolithic post-prefill
    /// transfer, bit-for-bit.
    pub pd_layer_groups: u32,
    /// Model link contention in the simulator: serialize concurrent EP
    /// and PD transfers sharing a source egress or destination ingress
    /// channel (one full-duplex NIC per instance) instead of letting
    /// them overlap for free, and account per-link busy/queueing time in
    /// `SimOutcome::links`. Off by default — transfers overlap freely,
    /// the idealized model this repo historically used — so enabling it
    /// only ever delays transfers, never speeds them up.
    pub link_contention: bool,
    /// Reallocation policy used when `role_switching` is on. `greedy`
    /// (the default) keeps the legacy one-instance-at-a-time controller
    /// bit-for-bit; `predictive` runs the online reallocation planner
    /// (`coordinator/planner.rs`): it scores topology neighborhoods
    /// against the profiled workload and emits ordered multi-step
    /// `SwitchPlan`s executed one step per monitor tick.
    pub planner: PlannerPolicy,
    /// Seconds between planning passes. 0 (the default) plans at every
    /// monitor tick — the legacy greedy cadence (the greedy controller's
    /// own cooldown remains the real rate limiter there).
    pub plan_interval: f64,
    /// `planner = "surrogate"` only: how many GP-ranked candidates per
    /// planning pass get an honest what-if evaluation. Default 3.
    pub surrogate_topk: usize,
    /// `planner = "surrogate"` only: posterior-variance floor above which
    /// a candidate is considered outside training support and forced into
    /// the honest set regardless of EI rank. Default 0.25.
    pub surrogate_min_var: f64,
    /// `planner = "surrogate"` only: seconds of synthetic arrivals each
    /// what-if simulation replays. Default 3.0 (floored at 0.5).
    pub whatif_horizon: f64,
    /// Real-engine monitor thread sample period, seconds. Default 0.1
    /// (the previously hard-coded 100 ms). The simulator's tick period
    /// stays `SimConfig::monitor_interval`.
    pub sample_interval: f64,
    /// Real-engine monitor EWMA weight in (0, 1]. Default 0.4 (the
    /// previously hard-coded value). The simulator keeps its own 0.3.
    pub monitor_alpha: f64,
    /// Fault-injection seed. 0 (the default) disables the chaos layer
    /// entirely — the simulator's fault plan stays empty and every run is
    /// bit-for-bit identical to a build without fault injection. Any
    /// non-zero value seeds a deterministic fault wave (see
    /// `sim::fault::FaultPlan::wave`) shaped by the `fault_*` knobs below.
    pub fault_seed: u64,
    /// Virtual time (seconds) the fault wave starts at.
    pub fault_wave_at: f64,
    /// Number of distinct instances the wave crashes (staggered).
    pub fault_crashes: u32,
    /// Seconds a crashed instance stays down before restarting.
    pub fault_downtime: f64,
    /// Link service-time multiplier during the wave (<= 1 disables link
    /// degradation).
    pub fault_link_factor: f64,
    /// Permanent service-time multiplier for straggler instances (<= 1
    /// disables stragglers).
    pub fault_straggler_factor: f64,
    /// The SLO-aware multi-path front door (`router/`). `Off` (the
    /// default) keeps the legacy single submit path bit-for-bit.
    pub router: RouterPolicy,
    /// TTFT target (seconds) the admission projection sheds against.
    /// `f64::INFINITY` (the default) never sheds on TTFT.
    pub router_slo_ttft: f64,
    /// TPOT target (seconds/token) for admission. `f64::INFINITY`
    /// (the default) never sheds on TPOT.
    pub router_slo_tpot: f64,
    /// Multiplier on both SLO targets before comparing the projection:
    /// < 1 sheds early (conservative), > 1 tolerates projected misses.
    pub router_headroom: f64,
    /// Per-instance queue-depth window the front door dispatches into;
    /// arrivals beyond it are held in the fair queues.
    pub router_depth: u32,
    /// Degrade mildly-over-SLO interactive requests (cap `max_tokens`
    /// to `router_degrade_tokens`, drop to the batch class) instead of
    /// shedding them outright.
    pub router_degrade: bool,
    /// `max_tokens` cap applied to degraded requests.
    pub router_degrade_tokens: u32,
    /// Floor for the `retry_after_ms` hint returned with a shed
    /// (HTTP 429) response.
    pub router_retry_after_ms: u64,
    /// Deficit weight for tenants not listed in `router_tenant_weights`.
    pub router_default_weight: u32,
    /// Per-tenant deficit weights, `"tenant:weight,..."` (e.g. `"0:4,7:2"`).
    /// Empty = every tenant at `router_default_weight`.
    pub router_tenant_weights: String,
    /// Engine supervision: heartbeat tracking, crash sweeps, exactly-once
    /// redispatch of in-flight work, deadline watchdog. Off by default —
    /// the engine is then bit-for-bit identical to pre-supervision builds.
    pub supervise: bool,
    /// An instance with no heartbeat for this long is marked dead and its
    /// in-flight work re-dispatched (0 disables staleness detection;
    /// panics are still caught and swept).
    pub supervise_heartbeat_ms: u64,
    /// Watchdog slack past a request's `deadline_ms` before its receiver
    /// is failed with a 504-style error.
    pub supervise_grace_ms: u64,
    /// Per-request redispatch budget after worker loss or stage errors.
    pub retry_limit: u32,
    /// Exponential-backoff base for redispatch (doubles per attempt,
    /// plus a deterministic seeded jitter).
    pub retry_base_ms: u64,
    /// `shutdown()` drain bound: > 0 stops intake and finishes (or fails
    /// with a structured error) in-flight requests within this window.
    /// 0 keeps the legacy immediate shutdown.
    pub drain_timeout_ms: u64,
    /// Deterministic engine fault injection (chaos testing): 0 = dormant
    /// (no faults, bit-for-bit identical behavior); nonzero seeds a
    /// worker-kill wave shaped by the `engine_fault_*` knobs below.
    pub engine_fault_seed: u64,
    /// Workers killed by the seeded wave (clamped to instances - 1).
    pub engine_fault_kills: u32,
    /// Jobs a doomed worker completes before its injected kill.
    pub engine_fault_after_jobs: u64,
    /// Injected per-job delay on one seeded straggler instance (0 = none).
    pub engine_fault_slow_ms: u64,
    /// Injected streamed EP/PD handoff failures (each exercises the
    /// per-request monolithic fallback).
    pub engine_fault_handoff_errors: u32,
    /// Health-aware control plane: per-instance circuit breakers on the
    /// dispatch path (`router/health.rs`). Off (the default) keeps
    /// dispatch fault-blind and bit-for-bit identical to prior builds.
    pub health_breaker: bool,
    /// Seconds a breaker stays Open after a failure before probing.
    pub health_open_secs: f64,
    /// Probe admissions granted when an Open breaker goes Half-Open.
    pub health_probes: u32,
    /// Failures inside `health_flap_window_secs` that escalate an
    /// instance from Open into quarantine.
    pub health_flap_threshold: u32,
    /// Width (seconds) of the flapping-detection window.
    pub health_flap_window_secs: f64,
    /// Base quarantine probation (seconds); doubles per repeat offence
    /// with deterministic seeded jitter on top.
    pub health_probation_secs: f64,
    /// Fault-aware replanning: Open/quarantined instances count zero
    /// capacity in topology scoring and a crash forces an out-of-band
    /// plan tick. Off by default.
    pub health_replan: bool,
    /// Hedged dispatch trigger quantile in (0, 1]: a request whose stage
    /// wait exceeds this quantile of observed waits gets a duplicate on a
    /// healthy sibling (first completion wins). 0 (the default) disables
    /// hedging entirely.
    pub hedge_quantile: f64,
    /// Stage-wait samples required before hedge thresholds engage.
    pub hedge_min_samples: u64,
    /// Cluster-wide redispatch budget, tokens per second: crash-wave
    /// retries beyond the bucket degrade to typed sheds instead of a
    /// retry storm. 0 (the default) leaves redispatch uncapped.
    pub retry_budget_per_s: f64,
    /// Burst capacity of the redispatch token bucket.
    pub retry_budget_burst: f64,
}

impl EpdConfig {
    /// EPD topology with uniform per-stage batch sizes.
    pub fn epd(topology: Topology, batch_e: u32, batch_p: u32, batch_d: u32) -> EpdConfig {
        let mut instances = Vec::new();
        for role in topology.roles() {
            let b = match role {
                Stage::Encode => batch_e,
                Stage::Prefill => batch_p,
                Stage::Decode => batch_d,
            };
            instances.push(InstanceConfig::new(role, b));
        }
        EpdConfig {
            mode: DeploymentMode::Epd,
            instances,
            sched_encode: SchedulingConfig::default(),
            sched_prefill: SchedulingConfig::default(),
            sched_decode: SchedulingConfig::default(),
            irp: true,
            role_switching: false,
            kv_frac: 0.5,
            mm_cache_entries: 3000,
            encoder_cache_tokens: 1 << 20,
            ep_chunk_tokens: 0,
            pd_layer_groups: 0,
            link_contention: false,
            planner: PlannerPolicy::Greedy,
            plan_interval: 0.0,
            surrogate_topk: 3,
            surrogate_min_var: 0.25,
            whatif_horizon: 3.0,
            sample_interval: 0.1,
            monitor_alpha: 0.4,
            fault_seed: 0,
            fault_wave_at: 5.0,
            fault_crashes: 1,
            fault_downtime: 5.0,
            fault_link_factor: 1.0,
            fault_straggler_factor: 1.0,
            router: RouterPolicy::Off,
            router_slo_ttft: f64::INFINITY,
            router_slo_tpot: f64::INFINITY,
            router_headroom: 1.0,
            router_depth: 4,
            router_degrade: false,
            router_degrade_tokens: 32,
            router_retry_after_ms: 250,
            router_default_weight: 1,
            router_tenant_weights: String::new(),
            supervise: false,
            supervise_heartbeat_ms: 1000,
            supervise_grace_ms: 250,
            retry_limit: 2,
            retry_base_ms: 25,
            drain_timeout_ms: 0,
            engine_fault_seed: 0,
            engine_fault_kills: 1,
            engine_fault_after_jobs: 4,
            engine_fault_slow_ms: 0,
            engine_fault_handoff_errors: 0,
            health_breaker: false,
            health_open_secs: 5.0,
            health_probes: 3,
            health_flap_threshold: 2,
            health_flap_window_secs: 60.0,
            health_probation_secs: 10.0,
            health_replan: false,
            hedge_quantile: 0.0,
            hedge_min_samples: 20,
            retry_budget_per_s: 0.0,
            retry_budget_burst: 10.0,
        }
    }

    /// DistServe-style PD disaggregation: `p` encode+prefill instances,
    /// `d` decode instances.
    pub fn distserve(p: u32, d: u32, batch_p: u32, batch_d: u32) -> EpdConfig {
        let mut cfg = EpdConfig::epd(Topology::new(0, p, d), 1, batch_p, batch_d);
        cfg.mode = DeploymentMode::PdDisagg;
        cfg.irp = false;
        cfg
    }

    /// vLLM-style aggregated serving on `n` instances.
    pub fn aggregated(n: u32, batch: u32) -> EpdConfig {
        let mut cfg = EpdConfig::epd(Topology::new(0, 0, n), 1, 1, batch);
        // Aggregated instances are all "decode" roles that run every stage.
        cfg.mode = DeploymentMode::Aggregated;
        cfg.irp = false;
        cfg
    }

    /// The instance topology (derived from roles).
    pub fn topology(&self) -> Topology {
        let mut t = Topology::new(0, 0, 0);
        for inst in &self.instances {
            t.set_count(inst.role, t.count(inst.role) + 1);
        }
        t
    }

    /// Total GPUs across instances.
    pub fn total_gpus(&self) -> u32 {
        self.instances.iter().map(|i| i.gpus()).sum()
    }

    pub fn sched_for(&self, stage: Stage) -> SchedulingConfig {
        match stage {
            Stage::Encode => self.sched_encode,
            Stage::Prefill => self.sched_prefill,
            Stage::Decode => self.sched_decode,
        }
    }

    /// Load from a TOML config file. Format:
    ///
    /// ```toml
    /// mode = "epd"            # epd | distserve | vllm
    /// topology = "5E2P1D"
    /// irp = true
    /// role_switching = false
    /// kv_frac = 0.5
    /// batch_encode = 1
    /// batch_prefill = 1
    /// batch_decode = 128
    /// encoder_cache_tokens = 1048576
    /// ep_chunk_tokens = 512   # 0 = monolithic EP handoff
    /// pd_layer_groups = 8     # 0 = monolithic PD (KV) handoff
    /// link_contention = false # serialize transfers sharing a link
    /// planner = "greedy"      # greedy | predictive | surrogate (reallocation policy)
    /// plan_interval = 0.0     # seconds between planning passes; 0 = every tick
    /// surrogate_topk = 3      # surrogate only: honest evals per planning pass
    /// surrogate_min_var = 0.25 # surrogate only: variance floor forcing exploration
    /// whatif_horizon = 3.0    # surrogate only: what-if sim horizon, seconds
    /// sample_interval = 0.1   # engine monitor sample period, seconds
    /// monitor_alpha = 0.4     # engine monitor EWMA weight
    /// fault_seed = 0          # 0 = chaos off; non-zero seeds a fault wave
    /// fault_wave_at = 5.0     # virtual seconds the wave starts at
    /// fault_crashes = 1       # instances crashed by the wave
    /// fault_downtime = 5.0    # seconds a crashed instance stays down
    /// fault_link_factor = 1.0 # link slow-down during the wave (1 = off)
    /// fault_straggler_factor = 1.0 # permanent straggler slow-down (1 = off)
    /// router = "off"          # off | on — SLO-aware multi-path front door
    /// router_slo_ttft = 2.6   # TTFT target, seconds (omit = never shed on TTFT)
    /// router_slo_tpot = 0.04  # TPOT target, seconds/token (omit = never shed)
    /// router_headroom = 1.0   # SLO multiplier; < 1 sheds early
    /// router_depth = 4        # per-instance dispatch window
    /// router_degrade = false  # cap + downgrade mild overload instead of shedding
    /// router_degrade_tokens = 32
    /// router_retry_after_ms = 250
    /// router_default_weight = 1
    /// router_tenant_weights = "0:4,7:2" # per-tenant deficit weights
    /// supervise = false       # engine supervision: heartbeats, redispatch, watchdog
    /// supervise_heartbeat_ms = 1000 # dead after this silence (0 = panics only)
    /// supervise_grace_ms = 250 # watchdog slack past a request deadline
    /// retry_limit = 2         # redispatch budget per request
    /// retry_base_ms = 25      # backoff base (doubles per attempt, seeded jitter)
    /// drain_timeout_ms = 0    # shutdown drain bound; 0 = immediate shutdown
    /// engine_fault_seed = 0   # 0 = engine chaos off; non-zero seeds a kill wave
    /// engine_fault_kills = 1  # workers killed by the wave
    /// engine_fault_after_jobs = 4 # jobs a doomed worker completes first
    /// engine_fault_slow_ms = 0 # injected straggler delay per job
    /// engine_fault_handoff_errors = 0 # injected streamed-handoff failures
    /// health_breaker = false  # circuit breakers on the dispatch path
    /// health_open_secs = 5.0  # Open hold before probing
    /// health_probes = 3       # Half-Open probe budget
    /// health_flap_threshold = 2 # failures in the window => quarantine
    /// health_flap_window_secs = 60.0
    /// health_probation_secs = 10.0 # base probation; doubles per offence
    /// health_replan = false   # unhealthy = zero capacity + emergency plan tick
    /// hedge_quantile = 0.0    # 0 = hedged dispatch off; e.g. 0.95
    /// hedge_min_samples = 20  # sketch warm-up before hedging engages
    /// retry_budget_per_s = 0.0 # 0 = cluster redispatch uncapped
    /// retry_budget_burst = 10.0
    /// [sched]
    /// queue = "fcfs"          # fcfs | sjf | slo-aware
    /// assign = "least-loaded" # round-robin | least-loaded
    /// ```
    pub fn from_toml(doc: &TomlDoc) -> anyhow::Result<EpdConfig> {
        use anyhow::Context;
        let mode = DeploymentMode::parse(doc.get_str("", "mode").unwrap_or("epd"))
            .context("bad 'mode'")?;
        let topo = Topology::parse(doc.get_str("", "topology").unwrap_or("2E1P1D"))
            .context("bad 'topology'")?;
        let be = doc.get_i64("", "batch_encode").unwrap_or(1) as u32;
        let bp = doc.get_i64("", "batch_prefill").unwrap_or(1) as u32;
        let bd = doc.get_i64("", "batch_decode").unwrap_or(128) as u32;
        let mut cfg = EpdConfig::epd(topo, be, bp, bd);
        cfg.mode = mode;
        cfg.irp = doc.get_bool("", "irp").unwrap_or(true);
        cfg.role_switching = doc.get_bool("", "role_switching").unwrap_or(false);
        cfg.kv_frac = doc.get_f64("", "kv_frac").unwrap_or(0.5);
        if let Some(t) = doc.get_i64("", "encoder_cache_tokens") {
            cfg.encoder_cache_tokens = t.max(0) as u64;
        }
        if let Some(t) = doc.get_i64("", "ep_chunk_tokens") {
            cfg.ep_chunk_tokens = t.max(0) as u64;
        }
        if let Some(g) = doc.get_i64("", "pd_layer_groups") {
            cfg.pd_layer_groups = g.max(0) as u32;
        }
        cfg.link_contention = doc.get_bool("", "link_contention").unwrap_or(false);
        if let Some(p) = doc.get_str("", "planner") {
            cfg.planner = PlannerPolicy::parse(p).context("bad 'planner'")?;
        }
        if let Some(v) = doc.get_f64("", "plan_interval") {
            cfg.plan_interval = v.max(0.0);
        }
        if let Some(v) = doc.get_i64("", "surrogate_topk") {
            cfg.surrogate_topk = v.max(1) as usize;
        }
        if let Some(v) = doc.get_f64("", "surrogate_min_var") {
            cfg.surrogate_min_var = v.max(0.0);
        }
        if let Some(v) = doc.get_f64("", "whatif_horizon") {
            cfg.whatif_horizon = v.max(0.5);
        }
        if let Some(v) = doc.get_f64("", "sample_interval") {
            cfg.sample_interval = v.max(0.001);
        }
        if let Some(v) = doc.get_f64("", "monitor_alpha") {
            cfg.monitor_alpha = v.clamp(0.01, 1.0);
        }
        if let Some(v) = doc.get_i64("", "fault_seed") {
            cfg.fault_seed = v.max(0) as u64;
        }
        if let Some(v) = doc.get_f64("", "fault_wave_at") {
            cfg.fault_wave_at = v.max(0.0);
        }
        if let Some(v) = doc.get_i64("", "fault_crashes") {
            cfg.fault_crashes = v.max(0) as u32;
        }
        if let Some(v) = doc.get_f64("", "fault_downtime") {
            cfg.fault_downtime = v.max(0.001);
        }
        if let Some(v) = doc.get_f64("", "fault_link_factor") {
            cfg.fault_link_factor = v.max(0.0);
        }
        if let Some(v) = doc.get_f64("", "fault_straggler_factor") {
            cfg.fault_straggler_factor = v.max(0.0);
        }
        if let Some(r) = doc.get_str("", "router") {
            cfg.router = RouterPolicy::parse(r).context("bad 'router'")?;
        }
        if let Some(v) = doc.get_f64("", "router_slo_ttft") {
            anyhow::ensure!(v > 0.0, "bad 'router_slo_ttft': must be > 0");
            cfg.router_slo_ttft = v;
        }
        if let Some(v) = doc.get_f64("", "router_slo_tpot") {
            anyhow::ensure!(v > 0.0, "bad 'router_slo_tpot': must be > 0");
            cfg.router_slo_tpot = v;
        }
        if let Some(v) = doc.get_f64("", "router_headroom") {
            anyhow::ensure!(v > 0.0, "bad 'router_headroom': must be > 0");
            cfg.router_headroom = v;
        }
        if let Some(v) = doc.get_i64("", "router_depth") {
            cfg.router_depth = v.max(1) as u32;
        }
        cfg.router_degrade = doc.get_bool("", "router_degrade").unwrap_or(false);
        if let Some(v) = doc.get_i64("", "router_degrade_tokens") {
            cfg.router_degrade_tokens = v.max(1) as u32;
        }
        if let Some(v) = doc.get_i64("", "router_retry_after_ms") {
            cfg.router_retry_after_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("", "router_default_weight") {
            cfg.router_default_weight = v.max(1) as u32;
        }
        if let Some(w) = doc.get_str("", "router_tenant_weights") {
            crate::router::parse_tenant_weights(w).context("bad 'router_tenant_weights'")?;
            cfg.router_tenant_weights = w.to_string();
        }
        cfg.supervise = doc.get_bool("", "supervise").unwrap_or(false);
        if let Some(v) = doc.get_i64("", "supervise_heartbeat_ms") {
            cfg.supervise_heartbeat_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("", "supervise_grace_ms") {
            cfg.supervise_grace_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("", "retry_limit") {
            cfg.retry_limit = v.max(0) as u32;
        }
        if let Some(v) = doc.get_i64("", "retry_base_ms") {
            cfg.retry_base_ms = v.max(1) as u64;
        }
        if let Some(v) = doc.get_i64("", "drain_timeout_ms") {
            cfg.drain_timeout_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("", "engine_fault_seed") {
            cfg.engine_fault_seed = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("", "engine_fault_kills") {
            cfg.engine_fault_kills = v.max(0) as u32;
        }
        if let Some(v) = doc.get_i64("", "engine_fault_after_jobs") {
            cfg.engine_fault_after_jobs = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("", "engine_fault_slow_ms") {
            cfg.engine_fault_slow_ms = v.max(0) as u64;
        }
        if let Some(v) = doc.get_i64("", "engine_fault_handoff_errors") {
            cfg.engine_fault_handoff_errors = v.max(0) as u32;
        }
        cfg.health_breaker = doc.get_bool("", "health_breaker").unwrap_or(false);
        if let Some(v) = doc.get_f64("", "health_open_secs") {
            cfg.health_open_secs = v.max(0.0);
        }
        if let Some(v) = doc.get_i64("", "health_probes") {
            cfg.health_probes = v.max(1) as u32;
        }
        if let Some(v) = doc.get_i64("", "health_flap_threshold") {
            cfg.health_flap_threshold = v.max(0) as u32;
        }
        if let Some(v) = doc.get_f64("", "health_flap_window_secs") {
            cfg.health_flap_window_secs = v.max(0.0);
        }
        if let Some(v) = doc.get_f64("", "health_probation_secs") {
            cfg.health_probation_secs = v.max(0.0);
        }
        cfg.health_replan = doc.get_bool("", "health_replan").unwrap_or(false);
        if let Some(v) = doc.get_f64("", "hedge_quantile") {
            anyhow::ensure!((0.0..=1.0).contains(&v), "bad 'hedge_quantile': must be in [0, 1]");
            cfg.hedge_quantile = v;
        }
        if let Some(v) = doc.get_i64("", "hedge_min_samples") {
            cfg.hedge_min_samples = v.max(1) as u64;
        }
        if let Some(v) = doc.get_f64("", "retry_budget_per_s") {
            cfg.retry_budget_per_s = v.max(0.0);
        }
        if let Some(v) = doc.get_f64("", "retry_budget_burst") {
            cfg.retry_budget_burst = v.max(1.0);
        }
        if let Some(q) = doc.get_str("sched", "queue") {
            let q = QueuePolicy::parse(q).context("bad sched.queue")?;
            cfg.sched_encode.queue = q;
            cfg.sched_prefill.queue = q;
            cfg.sched_decode.queue = q;
        }
        if let Some(a) = doc.get_str("sched", "assign") {
            let a = AssignPolicy::parse(a).context("bad sched.assign")?;
            cfg.sched_encode.assign = a;
            cfg.sched_prefill.assign = a;
            cfg.sched_decode.assign = a;
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders() {
        let cfg = EpdConfig::epd(Topology::new(5, 2, 1), 2, 1, 128);
        assert_eq!(cfg.instances.len(), 8);
        assert_eq!(cfg.topology(), Topology::new(5, 2, 1));
        assert_eq!(cfg.total_gpus(), 8);
        assert!(cfg.irp);
        assert_eq!(cfg.ep_chunk_tokens, 0, "streaming is opt-in");
        assert_eq!(cfg.pd_layer_groups, 0, "PD streaming is opt-in");
        assert!(!cfg.link_contention, "contention modelling is opt-in");
        assert_eq!(cfg.planner, PlannerPolicy::Greedy, "legacy policy is the default");
        assert_eq!(cfg.plan_interval, 0.0, "legacy cadence is the default");
        assert_eq!(cfg.surrogate_topk, 3);
        assert_eq!(cfg.surrogate_min_var, 0.25);
        assert_eq!(cfg.whatif_horizon, 3.0);
        assert_eq!(cfg.sample_interval, 0.1);
        assert_eq!(cfg.monitor_alpha, 0.4);
        assert_eq!(cfg.fault_seed, 0, "chaos is opt-in");
        assert_eq!(cfg.fault_link_factor, 1.0);
        assert_eq!(cfg.fault_straggler_factor, 1.0);
        assert_eq!(cfg.router, RouterPolicy::Off, "the front door is opt-in");
        assert_eq!(cfg.router_slo_ttft, f64::INFINITY, "no TTFT shedding by default");
        assert_eq!(cfg.router_slo_tpot, f64::INFINITY, "no TPOT shedding by default");
        assert_eq!(cfg.router_headroom, 1.0);
        assert_eq!(cfg.router_depth, 4);
        assert!(!cfg.router_degrade);
        assert_eq!(cfg.router_default_weight, 1);
        assert!(cfg.router_tenant_weights.is_empty());
        assert!(!cfg.supervise, "supervision is opt-in");
        assert_eq!(cfg.supervise_heartbeat_ms, 1000);
        assert_eq!(cfg.supervise_grace_ms, 250);
        assert_eq!(cfg.retry_limit, 2);
        assert_eq!(cfg.retry_base_ms, 25);
        assert_eq!(cfg.drain_timeout_ms, 0, "legacy shutdown is the default");
        assert_eq!(cfg.engine_fault_seed, 0, "engine chaos is opt-in");
        assert_eq!(cfg.engine_fault_kills, 1);
        assert_eq!(cfg.engine_fault_after_jobs, 4);
        assert_eq!(cfg.engine_fault_slow_ms, 0);
        assert_eq!(cfg.engine_fault_handoff_errors, 0);
        assert!(!cfg.health_breaker, "the breaker is opt-in");
        assert_eq!(cfg.health_open_secs, 5.0);
        assert_eq!(cfg.health_probes, 3);
        assert_eq!(cfg.health_flap_threshold, 2);
        assert_eq!(cfg.health_flap_window_secs, 60.0);
        assert_eq!(cfg.health_probation_secs, 10.0);
        assert!(!cfg.health_replan, "fault-aware replanning is opt-in");
        assert_eq!(cfg.hedge_quantile, 0.0, "hedged dispatch is opt-in");
        assert_eq!(cfg.hedge_min_samples, 20);
        assert_eq!(cfg.retry_budget_per_s, 0.0, "redispatch uncapped by default");
        assert_eq!(cfg.retry_budget_burst, 10.0);

        let ds = EpdConfig::distserve(7, 1, 1, 128);
        assert_eq!(ds.mode, DeploymentMode::PdDisagg);
        assert_eq!(ds.topology(), Topology::new(0, 7, 1));

        let agg = EpdConfig::aggregated(8, 64);
        assert_eq!(agg.mode, DeploymentMode::Aggregated);
        assert_eq!(agg.instances.len(), 8);
    }

    #[test]
    fn from_toml_full() {
        let doc = TomlDoc::parse(
            r#"
mode = "epd"
topology = "5E2P1D"
irp = true
kv_frac = 0.8
batch_decode = 64
encoder_cache_tokens = 4096
ep_chunk_tokens = 512
pd_layer_groups = 8
link_contention = true
planner = "surrogate"
plan_interval = 2.5
surrogate_topk = 5
surrogate_min_var = 0.5
whatif_horizon = 4.0
sample_interval = 0.05
monitor_alpha = 0.25
fault_seed = 7
fault_wave_at = 12.0
fault_crashes = 2
fault_downtime = 3.5
fault_link_factor = 4.0
fault_straggler_factor = 1.5
router = "on"
router_slo_ttft = 2.6
router_slo_tpot = 0.04
router_headroom = 0.9
router_depth = 8
router_degrade = true
router_degrade_tokens = 16
router_retry_after_ms = 500
router_default_weight = 2
router_tenant_weights = "0:4,7:2"
supervise = true
supervise_heartbeat_ms = 400
supervise_grace_ms = 100
retry_limit = 3
retry_base_ms = 10
drain_timeout_ms = 2000
engine_fault_seed = 99
engine_fault_kills = 2
engine_fault_after_jobs = 6
engine_fault_slow_ms = 15
engine_fault_handoff_errors = 1
health_breaker = true
health_open_secs = 2.0
health_probes = 5
health_flap_threshold = 3
health_flap_window_secs = 30.0
health_probation_secs = 8.0
health_replan = true
hedge_quantile = 0.95
hedge_min_samples = 10
retry_budget_per_s = 4.0
retry_budget_burst = 20.0
[sched]
queue = "sjf"
assign = "round-robin"
"#,
        )
        .unwrap();
        let cfg = EpdConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.topology(), Topology::new(5, 2, 1));
        assert_eq!(cfg.kv_frac, 0.8);
        assert_eq!(cfg.encoder_cache_tokens, 4096);
        assert_eq!(cfg.ep_chunk_tokens, 512);
        assert_eq!(cfg.pd_layer_groups, 8);
        assert!(cfg.link_contention);
        assert_eq!(cfg.planner, PlannerPolicy::Surrogate);
        assert_eq!(cfg.plan_interval, 2.5);
        assert_eq!(cfg.surrogate_topk, 5);
        assert_eq!(cfg.surrogate_min_var, 0.5);
        assert_eq!(cfg.whatif_horizon, 4.0);
        assert_eq!(cfg.sample_interval, 0.05);
        assert_eq!(cfg.monitor_alpha, 0.25);
        assert_eq!(cfg.fault_seed, 7);
        assert_eq!(cfg.fault_wave_at, 12.0);
        assert_eq!(cfg.fault_crashes, 2);
        assert_eq!(cfg.fault_downtime, 3.5);
        assert_eq!(cfg.fault_link_factor, 4.0);
        assert_eq!(cfg.fault_straggler_factor, 1.5);
        assert_eq!(cfg.router, RouterPolicy::On);
        assert_eq!(cfg.router_slo_ttft, 2.6);
        assert_eq!(cfg.router_slo_tpot, 0.04);
        assert_eq!(cfg.router_headroom, 0.9);
        assert_eq!(cfg.router_depth, 8);
        assert!(cfg.router_degrade);
        assert_eq!(cfg.router_degrade_tokens, 16);
        assert_eq!(cfg.router_retry_after_ms, 500);
        assert_eq!(cfg.router_default_weight, 2);
        assert_eq!(cfg.router_tenant_weights, "0:4,7:2");
        assert!(cfg.supervise);
        assert_eq!(cfg.supervise_heartbeat_ms, 400);
        assert_eq!(cfg.supervise_grace_ms, 100);
        assert_eq!(cfg.retry_limit, 3);
        assert_eq!(cfg.retry_base_ms, 10);
        assert_eq!(cfg.drain_timeout_ms, 2000);
        assert_eq!(cfg.engine_fault_seed, 99);
        assert_eq!(cfg.engine_fault_kills, 2);
        assert_eq!(cfg.engine_fault_after_jobs, 6);
        assert_eq!(cfg.engine_fault_slow_ms, 15);
        assert_eq!(cfg.engine_fault_handoff_errors, 1);
        assert!(cfg.health_breaker);
        assert_eq!(cfg.health_open_secs, 2.0);
        assert_eq!(cfg.health_probes, 5);
        assert_eq!(cfg.health_flap_threshold, 3);
        assert_eq!(cfg.health_flap_window_secs, 30.0);
        assert_eq!(cfg.health_probation_secs, 8.0);
        assert!(cfg.health_replan);
        assert_eq!(cfg.hedge_quantile, 0.95);
        assert_eq!(cfg.hedge_min_samples, 10);
        assert_eq!(cfg.retry_budget_per_s, 4.0);
        assert_eq!(cfg.retry_budget_burst, 20.0);
        assert_eq!(cfg.sched_decode.queue, QueuePolicy::Sjf);
        assert_eq!(cfg.sched_encode.assign, AssignPolicy::RoundRobin);
        let d = cfg.instances.iter().find(|i| i.role == Stage::Decode).unwrap();
        assert_eq!(d.max_batch, 64);
    }

    #[test]
    fn from_toml_rejects_bad_mode() {
        let doc = TomlDoc::parse("mode = \"nope\"").unwrap();
        assert!(EpdConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(QueuePolicy::parse("FCFS"), Some(QueuePolicy::Fcfs));
        assert_eq!(AssignPolicy::parse("least-loaded"), Some(AssignPolicy::LeastLoaded));
        assert_eq!(QueuePolicy::parse("??"), None);
        assert_eq!(PlannerPolicy::parse("Predictive"), Some(PlannerPolicy::Predictive));
        assert_eq!(PlannerPolicy::parse("greedy"), Some(PlannerPolicy::Greedy));
        assert_eq!(PlannerPolicy::parse("Surrogate"), Some(PlannerPolicy::Surrogate));
        assert_eq!(PlannerPolicy::Surrogate.name(), "surrogate");
        assert_eq!(PlannerPolicy::parse("??"), None);
    }

    #[test]
    fn from_toml_rejects_bad_planner() {
        let doc = TomlDoc::parse("planner = \"oracle\"").unwrap();
        assert!(EpdConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn from_toml_rejects_bad_router() {
        let doc = TomlDoc::parse("router = \"auto\"").unwrap();
        assert!(EpdConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("router_tenant_weights = \"0;4\"").unwrap();
        assert!(EpdConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("router_slo_ttft = -1.0").unwrap();
        assert!(EpdConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn from_toml_rejects_bad_hedge_quantile() {
        let doc = TomlDoc::parse("hedge_quantile = 1.5").unwrap();
        assert!(EpdConfig::from_toml(&doc).is_err());
        let doc = TomlDoc::parse("hedge_quantile = -0.1").unwrap();
        assert!(EpdConfig::from_toml(&doc).is_err());
    }

    #[test]
    fn router_policy_parsing() {
        assert_eq!(RouterPolicy::parse("ON"), Some(RouterPolicy::On));
        assert_eq!(RouterPolicy::parse("off"), Some(RouterPolicy::Off));
        assert_eq!(RouterPolicy::parse("??"), None);
        assert_eq!(RouterPolicy::On.name(), "on");
        assert_eq!(QueuePolicy::parse("priority"), Some(QueuePolicy::Priority));
        assert_eq!(QueuePolicy::Priority.name(), "priority");
    }
}
