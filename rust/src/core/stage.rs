//! The three pipeline stages of §3.1.

/// A pipeline stage an instance can serve.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Stage {
    /// Multimodal encoding: raw media → MM tokens.
    Encode,
    /// Prefill: MM tokens + prompt → KV cache + first token.
    Prefill,
    /// Decode: autoregressive generation.
    Decode,
}

impl Stage {
    pub const ALL: [Stage; 3] = [Stage::Encode, Stage::Prefill, Stage::Decode];

    /// The canonical array index of this stage (E = 0, P = 1, D = 2) —
    /// the single stage→index mapping shared by the queue monitor, the
    /// reallocation planner and both engines' per-stage arrays.
    pub const fn index(self) -> usize {
        match self {
            Stage::Encode => 0,
            Stage::Prefill => 1,
            Stage::Decode => 2,
        }
    }

    /// One-letter code used in configuration strings like "5E2P1D".
    pub fn code(&self) -> char {
        match self {
            Stage::Encode => 'E',
            Stage::Prefill => 'P',
            Stage::Decode => 'D',
        }
    }

    pub fn from_code(c: char) -> Option<Stage> {
        match c.to_ascii_uppercase() {
            'E' => Some(Stage::Encode),
            'P' => Some(Stage::Prefill),
            'D' => Some(Stage::Decode),
            _ => None,
        }
    }

    /// The downstream stage a request migrates to, if any.
    pub fn next(&self) -> Option<Stage> {
        match self {
            Stage::Encode => Some(Stage::Prefill),
            Stage::Prefill => Some(Stage::Decode),
            Stage::Decode => None,
        }
    }
}

impl std::fmt::Display for Stage {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Stage::Encode => "encode",
            Stage::Prefill => "prefill",
            Stage::Decode => "decode",
        };
        f.write_str(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip() {
        for s in Stage::ALL {
            assert_eq!(Stage::from_code(s.code()), Some(s));
        }
        assert_eq!(Stage::from_code('x'), None);
        assert_eq!(Stage::from_code('e'), Some(Stage::Encode));
    }

    #[test]
    fn index_is_canonical_order() {
        for (i, s) in Stage::ALL.into_iter().enumerate() {
            assert_eq!(s.index(), i);
        }
    }

    #[test]
    fn pipeline_order() {
        assert_eq!(Stage::Encode.next(), Some(Stage::Prefill));
        assert_eq!(Stage::Prefill.next(), Some(Stage::Decode));
        assert_eq!(Stage::Decode.next(), None);
    }
}
