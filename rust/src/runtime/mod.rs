//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! and executes them from the serving hot path. Python never runs here.
//!
//! - [`manifest`] parses `artifacts/manifest.json` (weight table, bucket
//!   index, model config).
//! - [`tiny_lmm`] owns the PJRT client, the device-resident weight buffers
//!   and one compiled executable per shape bucket, and exposes typed
//!   `encode` / `prefill` / `decode_step` calls.

pub mod manifest;
pub mod tiny_lmm;

pub use manifest::{Manifest};
pub use tiny_lmm::{DecodeState, PrefillOutput, TinyLmmRuntime};
