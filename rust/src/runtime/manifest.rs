//! `artifacts/manifest.json` parsing — the contract between `aot.py` and
//! the rust runtime: weight table (name, shape, byte offset into
//! weights.bin, in HLO parameter order), per-bucket artifact index and the
//! tiny-LMM dimensions.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context};

use crate::util::json::Json;

/// One weight tensor in `weights.bin`.
#[derive(Debug, Clone, PartialEq)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size_bytes: usize,
}

/// One compiled-shape bucket.
#[derive(Debug, Clone, PartialEq)]
pub struct Bucket {
    /// Bucket key: tiles (encode), images (prefill) or batch (decode).
    pub key: u32,
    pub file: String,
    /// Prefill only: padded token length of the bucket.
    pub tokens: u32,
    /// Prefill only: MM token count.
    pub mm_tokens: u32,
    /// Decode only: companion executable that slices the logits prefix
    /// from the fused state (CPU PJRT lacks partial raw host reads).
    pub logits_file: Option<String>,
}

/// Tiny-LMM dimensions (mirrors python/compile/configs.py).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TinyConfig {
    pub vis_num_patches: u32,
    pub vis_patch_dim: u32,
    pub vis_out_tokens: u32,
    pub llm_hidden: u32,
    pub llm_layers: u32,
    pub llm_heads: u32,
    pub llm_head_dim: u32,
    pub llm_vocab: u32,
    pub llm_max_seq: u32,
    pub prefill_text: u32,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub weights: Vec<WeightEntry>,
    pub encode: Vec<Bucket>,
    pub prefill: Vec<Bucket>,
    pub decode: Vec<Bucket>,
    pub config: TinyConfig,
}

fn req_u64(j: &Json, key: &str) -> anyhow::Result<u64> {
    j.get(key)
        .and_then(|v| v.as_u64())
        .with_context(|| format!("manifest missing numeric '{key}'"))
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        if req_u64(&j, "format_version")? != 1 {
            bail!("unsupported manifest format_version");
        }

        let mut weights = Vec::new();
        for w in j.get("weights").and_then(|v| v.as_arr()).context("weights[]")? {
            weights.push(WeightEntry {
                name: w.get("name").and_then(|v| v.as_str()).context("weight name")?.to_string(),
                shape: w
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .context("weight shape")?
                    .iter()
                    .map(|x| x.as_u64().unwrap_or(0) as usize)
                    .collect(),
                offset: req_u64(w, "offset")? as usize,
                size_bytes: req_u64(w, "size_bytes")? as usize,
            });
        }
        // The weight table must be sorted by name (HLO parameter order).
        for pair in weights.windows(2) {
            if pair[0].name >= pair[1].name {
                bail!("weight table not sorted: {} >= {}", pair[0].name, pair[1].name);
            }
        }

        let arts = j.get("artifacts").context("artifacts{}")?;
        let parse_group = |group: &str, key_field: &str| -> anyhow::Result<Vec<Bucket>> {
            let mut out = Vec::new();
            for a in arts.get(group).and_then(|v| v.as_arr()).context("artifact group")? {
                out.push(Bucket {
                    key: req_u64(a, key_field)? as u32,
                    file: a.get("file").and_then(|v| v.as_str()).context("file")?.to_string(),
                    tokens: a.get("tokens").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                    mm_tokens: a.get("mm_tokens").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                    logits_file: a
                        .get("logits_file")
                        .and_then(|v| v.as_str())
                        .map(|s| s.to_string()),
                });
            }
            out.sort_by_key(|b| b.key);
            Ok(out)
        };

        let cfg = j.get("config").context("config{}")?;
        let vis = cfg.get("vision").context("config.vision")?;
        let llm = cfg.get("llm").context("config.llm")?;
        let buckets = cfg.get("buckets").context("config.buckets")?;
        let config = TinyConfig {
            vis_num_patches: req_u64(vis, "num_patches")? as u32,
            vis_patch_dim: req_u64(vis, "patch_dim")? as u32,
            vis_out_tokens: req_u64(vis, "out_tokens")? as u32,
            llm_hidden: req_u64(llm, "hidden")? as u32,
            llm_layers: req_u64(llm, "layers")? as u32,
            llm_heads: req_u64(llm, "heads")? as u32,
            llm_head_dim: req_u64(llm, "head_dim")? as u32,
            llm_vocab: req_u64(llm, "vocab")? as u32,
            llm_max_seq: req_u64(llm, "max_seq")? as u32,
            prefill_text: req_u64(buckets, "prefill_text")? as u32,
        };

        Ok(Manifest {
            dir,
            weights,
            encode: parse_group("encode", "tiles")?,
            prefill: parse_group("prefill", "images")?,
            decode: parse_group("decode", "batch")?,
            config,
        })
    }

    /// Read weights.bin as f32 tensors in table order.
    pub fn load_weights(&self) -> anyhow::Result<Vec<(WeightEntry, Vec<f32>)>> {
        let path = self.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut out = Vec::with_capacity(self.weights.len());
        for w in &self.weights {
            let end = w.offset + w.size_bytes;
            if end > bytes.len() {
                bail!("weights.bin truncated at {}", w.name);
            }
            let data: Vec<f32> = bytes[w.offset..end]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let expect: usize = w.shape.iter().product::<usize>().max(1);
            if data.len() != expect {
                bail!("weight {}: {} elements, expected {}", w.name, data.len(), expect);
            }
            out.push((w.clone(), data));
        }
        Ok(out)
    }

    /// Smallest bucket with key ≥ `need` (shape-bucket selection).
    pub fn pick_bucket(buckets: &[Bucket], need: u32) -> Option<&Bucket> {
        buckets.iter().find(|b| b.key >= need)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        assert_eq!(m.weights.len(), 69);
        assert_eq!(m.config.llm_vocab, 512);
        assert_eq!(m.config.vis_out_tokens, 16);
        assert!(!m.encode.is_empty() && !m.prefill.is_empty() && !m.decode.is_empty());
        // Weight offsets are contiguous.
        let mut off = 0;
        for w in &m.weights {
            assert_eq!(w.offset, off);
            off += w.size_bytes;
        }
    }

    #[test]
    fn loads_weights_bin() {
        if !artifacts_available() {
            return;
        }
        let m = Manifest::load("artifacts").unwrap();
        let ws = m.load_weights().unwrap();
        assert_eq!(ws.len(), 69);
        // Every tensor has finite values.
        for (e, data) in &ws {
            assert!(data.iter().all(|x| x.is_finite()), "{} has non-finite", e.name);
        }
    }

    #[test]
    fn bucket_selection() {
        let buckets = vec![
            Bucket { key: 1, file: "a".into(), tokens: 0, mm_tokens: 0, logits_file: None },
            Bucket { key: 4, file: "b".into(), tokens: 0, mm_tokens: 0, logits_file: None },
            Bucket { key: 8, file: "c".into(), tokens: 0, mm_tokens: 0, logits_file: None },
        ];
        assert_eq!(Manifest::pick_bucket(&buckets, 1).unwrap().key, 1);
        assert_eq!(Manifest::pick_bucket(&buckets, 2).unwrap().key, 4);
        assert_eq!(Manifest::pick_bucket(&buckets, 8).unwrap().key, 8);
        assert!(Manifest::pick_bucket(&buckets, 9).is_none());
    }
}
