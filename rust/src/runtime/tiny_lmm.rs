//! Tiny-LMM execution over PJRT (CPU plugin).
//!
//! Each engine instance owns one `TinyLmmRuntime` (its "device"): a PJRT
//! client, device-resident weight buffers and lazily-compiled executables
//! per shape bucket. The `xla` crate's client is `Rc`-based (not `Send`),
//! so runtimes are created *inside* the instance thread — never shared.
//!
//! Hot-path design:
//! - weights are uploaded once per (client, role) and passed by reference
//!   to every `execute_b` call;
//! - the decode state `[logits | kv]` is a single device buffer fed back
//!   each step; only the `B × vocab` logits prefix is copied to the host
//!   per step via a tiny companion "slicer" executable (the CPU plugin
//!   lacks partial raw host reads), so the KV cache never round-trips.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::{Bucket, Manifest, TinyConfig};

/// Output of a prefill call.
#[derive(Debug, Clone)]
pub struct PrefillOutput {
    /// Last-position logits, `[vocab]`.
    pub logits: Vec<f32>,
    /// Flattened per-sequence KV cache, `[L, 2, H, max_seq, D]`.
    pub kv: Vec<f32>,
    /// Sequence length represented in the KV cache.
    pub len: i32,
}

/// A running decode batch whose fused state lives on the device.
pub struct DecodeState {
    /// Bucket batch size (slots).
    pub batch: u32,
    /// Per-slot current sequence length.
    pub lens: Vec<i32>,
    state_buf: PjRtBuffer,
    state_len: usize,
}

impl DecodeState {
    pub fn state_len(&self) -> usize {
        self.state_len
    }
}

/// Per-instance runtime.
pub struct TinyLmmRuntime {
    client: PjRtClient,
    manifest: Manifest,
    /// Host copies of the weights (kept for re-upload after role switch
    /// compaction; ~16 MB).
    host_weights: Vec<(Vec<usize>, Vec<f32>)>,
    weight_bufs: Vec<PjRtBuffer>,
    encode_exes: BTreeMap<u32, PjRtLoadedExecutable>,
    prefill_exes: BTreeMap<u32, PjRtLoadedExecutable>,
    decode_exes: BTreeMap<u32, PjRtLoadedExecutable>,
    /// Logits-prefix slicers, one per decode bucket (see decode_step).
    decode_logits_exes: BTreeMap<u32, PjRtLoadedExecutable>,
}

impl TinyLmmRuntime {
    /// Load manifest + weights and create the PJRT client. Executables are
    /// compiled lazily per bucket (mimics per-role model loading).
    pub fn load(artifacts_dir: &str) -> Result<TinyLmmRuntime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let host_weights: Vec<(Vec<usize>, Vec<f32>)> = manifest
            .load_weights()?
            .into_iter()
            .map(|(e, data)| (e.shape, data))
            .collect();
        let mut rt = TinyLmmRuntime {
            client,
            manifest,
            host_weights,
            weight_bufs: Vec::new(),
            encode_exes: BTreeMap::new(),
            prefill_exes: BTreeMap::new(),
            decode_exes: BTreeMap::new(),
            decode_logits_exes: BTreeMap::new(),
        };
        rt.upload_weights()?;
        Ok(rt)
    }

    pub fn config(&self) -> &TinyConfig {
        &self.manifest.config
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn upload_weights(&mut self) -> Result<()> {
        self.weight_bufs.clear();
        for (shape, data) in &self.host_weights {
            let dims: Vec<usize> = if shape.is_empty() { vec![] } else { shape.clone() };
            let buf = self
                .client
                .buffer_from_host_buffer(data, &dims, None)
                .context("uploading weight")?;
            self.weight_bufs.push(buf);
        }
        Ok(())
    }

    fn compile(&self, file: &str) -> Result<PjRtLoadedExecutable> {
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }

    /// Ensure the executables a role needs exist (encode / prefill /
    /// decode). Called on startup and after role switches.
    pub fn warm_encode(&mut self) -> Result<()> {
        let buckets: Vec<Bucket> = self.manifest.encode.clone();
        for b in buckets {
            if !self.encode_exes.contains_key(&b.key) {
                let exe = self.compile(&b.file)?;
                self.encode_exes.insert(b.key, exe);
            }
        }
        Ok(())
    }

    pub fn warm_prefill(&mut self) -> Result<()> {
        let buckets: Vec<Bucket> = self.manifest.prefill.clone();
        for b in buckets {
            if !self.prefill_exes.contains_key(&b.key) {
                let exe = self.compile(&b.file)?;
                self.prefill_exes.insert(b.key, exe);
            }
        }
        Ok(())
    }

    pub fn warm_decode(&mut self) -> Result<()> {
        let buckets: Vec<Bucket> = self.manifest.decode.clone();
        for b in buckets {
            self.ensure_decode(&b)?;
        }
        Ok(())
    }

    fn ensure_decode(&mut self, b: &Bucket) -> Result<()> {
        if !self.decode_exes.contains_key(&b.key) {
            let exe = self.compile(&b.file)?;
            self.decode_exes.insert(b.key, exe);
            let lf = b
                .logits_file
                .as_ref()
                .context("decode bucket missing logits_file")?;
            let lexe = self.compile(lf)?;
            self.decode_logits_exes.insert(b.key, lexe);
        }
        Ok(())
    }

    /// Per-sequence flattened KV length: L × 2 × H × S × D.
    pub fn kv_len(&self) -> usize {
        let c = &self.manifest.config;
        (c.llm_layers * 2 * c.llm_heads * c.llm_max_seq * c.llm_head_dim) as usize
    }

    /// Encode `tiles` image tiles. `patches` is `[tiles, num_patches,
    /// patch_dim]` flattened. Returns MM tokens `[tiles, out_tokens,
    /// hidden]` flattened.
    pub fn encode(&mut self, patches: &[f32], tiles: u32) -> Result<Vec<f32>> {
        let c = self.manifest.config;
        let per_tile = (c.vis_num_patches * c.vis_patch_dim) as usize;
        if patches.len() != per_tile * tiles as usize {
            bail!("encode: got {} floats for {tiles} tiles", patches.len());
        }
        let bucket = Manifest::pick_bucket(&self.manifest.encode, tiles)
            .with_context(|| format!("no encode bucket ≥ {tiles} tiles"))?
            .clone();
        if !self.encode_exes.contains_key(&bucket.key) {
            let exe = self.compile(&bucket.file)?;
            self.encode_exes.insert(bucket.key, exe);
        }

        // Pad to the bucket.
        let mut padded = patches.to_vec();
        padded.resize(per_tile * bucket.key as usize, 0.0);
        let input = self.client.buffer_from_host_buffer(
            &padded,
            &[
                bucket.key as usize,
                c.vis_num_patches as usize,
                c.vis_patch_dim as usize,
            ],
            None,
        )?;

        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&input);
        let exe = &self.encode_exes[&bucket.key];
        let result = exe.execute_b(&args)?;
        let lit = result[0][0].to_literal_sync()?.to_tuple1()?;
        let full: Vec<f32> = lit.to_vec()?;
        let per_tile_out = (c.vis_out_tokens * c.llm_hidden) as usize;
        Ok(full[..per_tile_out * tiles as usize].to_vec())
    }

    /// Prefill a sequence. `images` picks the bucket; `tokens` must already
    /// be padded to the bucket's token length (see
    /// [`Self::prefill_bucket_tokens`]); `mm` is padded/truncated here.
    pub fn prefill(
        &mut self,
        images: u32,
        tokens: &[i32],
        mm: &[f32],
        len: i32,
    ) -> Result<PrefillOutput> {
        let c = self.manifest.config;
        let bucket = Manifest::pick_bucket(&self.manifest.prefill, images.max(1))
            .with_context(|| format!("no prefill bucket ≥ {images} images"))?
            .clone();
        if tokens.len() != bucket.tokens as usize {
            bail!(
                "prefill: {} tokens given, bucket i{} wants {}",
                tokens.len(),
                bucket.key,
                bucket.tokens
            );
        }
        if !self.prefill_exes.contains_key(&bucket.key) {
            let exe = self.compile(&bucket.file)?;
            self.prefill_exes.insert(bucket.key, exe);
        }

        let mm_len = (bucket.mm_tokens * c.llm_hidden) as usize;
        let mut mm_padded = mm.to_vec();
        mm_padded.resize(mm_len, 0.0);

        let tok_buf = self
            .client
            .buffer_from_host_buffer(tokens, &[tokens.len()], None)?;
        let mm_buf = self.client.buffer_from_host_buffer(
            &mm_padded,
            &[bucket.mm_tokens as usize, c.llm_hidden as usize],
            None,
        )?;
        let len_buf = self.client.buffer_from_host_buffer(&[len], &[], None)?;

        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&mm_buf);
        args.push(&len_buf);
        let exe = &self.prefill_exes[&bucket.key];
        let result = exe.execute_b(&args)?;
        let (logits_lit, kv_lit) = result[0][0].to_literal_sync()?.to_tuple2()?;
        Ok(PrefillOutput {
            logits: logits_lit.to_vec()?,
            kv: kv_lit.to_vec()?,
            len,
        })
    }

    /// Padded token length of the prefill bucket covering `images`.
    pub fn prefill_bucket_tokens(&self, images: u32) -> Result<(u32, u32)> {
        let b = Manifest::pick_bucket(&self.manifest.prefill, images.max(1))
            .with_context(|| format!("no prefill bucket ≥ {images} images"))?;
        Ok((b.tokens, b.mm_tokens))
    }

    /// Assemble a decode batch from per-sequence prefill KVs and upload the
    /// fused state to the device.
    pub fn decode_start(&mut self, kvs: &[&[f32]], lens: &[i32]) -> Result<DecodeState> {
        let c = self.manifest.config;
        assert_eq!(kvs.len(), lens.len());
        let n = kvs.len() as u32;
        let bucket = Manifest::pick_bucket(&self.manifest.decode, n.max(1))
            .with_context(|| format!("no decode bucket ≥ batch {n}"))?
            .clone();
        self.ensure_decode(&bucket)?;
        let b = bucket.key as usize;
        let v = c.llm_vocab as usize;
        let slab = (c.llm_heads * c.llm_max_seq * c.llm_head_dim) as usize; // per (l, c, seq)
        let lc = (c.llm_layers * 2) as usize;
        let kv_seq = self.kv_len();
        let state_len = b * v + lc * b * slab;

        let mut state = vec![0.0f32; state_len];
        // Interleave per-seq [L, 2, H, S, D] into [L, 2, B, H, S, D].
        for (bi, kv) in kvs.iter().enumerate() {
            if kv.len() != kv_seq {
                bail!("decode_start: kv[{bi}] has {} floats, want {kv_seq}", kv.len());
            }
            for lci in 0..lc {
                let src = &kv[lci * slab..(lci + 1) * slab];
                let dst_off = b * v + (lci * b + bi) * slab;
                state[dst_off..dst_off + slab].copy_from_slice(src);
            }
        }
        let mut lens_padded = lens.to_vec();
        lens_padded.resize(b, 1); // idle slots decode garbage at pos 1, ignored
        let state_buf = self
            .client
            .buffer_from_host_buffer(&state, &[state_len], None)?;
        Ok(DecodeState {
            batch: bucket.key,
            lens: lens_padded,
            state_buf,
            state_len,
        })
    }

    /// One decode step: feeds `tokens` (one per slot) and returns the new
    /// logits `[batch, vocab]`. The KV stays on the device.
    pub fn decode_step(&mut self, state: &mut DecodeState, tokens: &[i32]) -> Result<Vec<f32>> {
        let c = self.manifest.config;
        let b = state.batch as usize;
        if tokens.len() != b {
            bail!("decode_step: {} tokens for batch {b}", tokens.len());
        }
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &[b], None)?;
        let len_buf = self
            .client
            .buffer_from_host_buffer(&state.lens, &[b], None)?;
        let exe = &self.decode_exes[&state.batch];
        let mut args: Vec<&PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&state.state_buf);
        args.push(&len_buf);
        let mut result = exe.execute_b(&args)?;
        let new_state = result[0].remove(0);

        // Only the logits prefix comes back to the host, via the companion
        // slicer executable — the fused state stays on the device (the CPU
        // PJRT plugin does not implement partial raw host copies).
        let lexe = &self.decode_logits_exes[&state.batch];
        let lit = lexe.execute_b(&[&new_state])?[0][0]
            .to_literal_sync()?
            .to_tuple1()?;
        let logits: Vec<f32> = lit.to_vec()?;
        debug_assert_eq!(logits.len(), b * c.llm_vocab as usize);

        state.state_buf = new_state;
        for l in &mut state.lens {
            *l += 1;
        }
        Ok(logits)
    }

    /// Pull the full state back to the host and split out each slot's KV
    /// (`[L, 2, H, S, D]` flattened) — used when a batch re-forms.
    pub fn decode_extract(&mut self, state: &DecodeState) -> Result<Vec<Vec<f32>>> {
        let c = self.manifest.config;
        let b = state.batch as usize;
        let v = c.llm_vocab as usize;
        let slab = (c.llm_heads * c.llm_max_seq * c.llm_head_dim) as usize;
        let lc = (c.llm_layers * 2) as usize;
        let full: Vec<f32> = state.state_buf.to_literal_sync()?.to_vec()?;
        debug_assert_eq!(full.len(), state.state_len);
        let mut out = Vec::with_capacity(b);
        for bi in 0..b {
            let mut kv = vec![0.0f32; lc * slab];
            for lci in 0..lc {
                let src_off = b * v + (lci * b + bi) * slab;
                kv[lci * slab..(lci + 1) * slab]
                    .copy_from_slice(&full[src_off..src_off + slab]);
            }
            out.push(kv);
        }
        Ok(out)
    }
}

/// Greedy sampling: argmax over one slot's logits.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for i in 1..logits.len() {
        if logits[i] > logits[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    /// End-to-end through PJRT: encode → prefill → decode 4 tokens, and
    /// check decode-vs-prefill consistency exactly like the python test.
    #[test]
    fn full_pipeline_through_pjrt() {
        if !artifacts_available() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = TinyLmmRuntime::load("artifacts").unwrap();
        let c = *rt.config();

        // Synthetic image tile.
        let per_tile = (c.vis_num_patches * c.vis_patch_dim) as usize;
        let patches: Vec<f32> = (0..per_tile).map(|i| (i % 255) as f32 / 255.0).collect();
        let mm = rt.encode(&patches, 1).unwrap();
        assert_eq!(mm.len(), (c.vis_out_tokens * c.llm_hidden) as usize);
        assert!(mm.iter().all(|x| x.is_finite()));

        // Prefill: [BOS, 16 placeholders, 'h', 'i'] padded to the bucket.
        let (bucket_tokens, mm_tokens) = rt.prefill_bucket_tokens(1).unwrap();
        let mut tokens = vec![256i32]; // BOS
        tokens.extend(std::iter::repeat(258).take(mm_tokens as usize));
        tokens.extend([104, 105]); // "hi"
        let len = tokens.len() as i32;
        tokens.resize(bucket_tokens as usize, 259); // PAD
        let pf = rt.prefill(1, &tokens, &mm, len).unwrap();
        assert_eq!(pf.logits.len(), c.llm_vocab as usize);
        assert!(pf.logits.iter().all(|x| x.is_finite()));

        // Decode 4 greedy tokens with device-resident state.
        let first = argmax(&pf.logits);
        let mut state = rt.decode_start(&[&pf.kv], &[len]).unwrap();
        let mut cur = first;
        let mut generated = vec![first];
        for _ in 0..3 {
            let logits = rt.decode_step(&mut state, &[cur]).unwrap();
            cur = argmax(&logits[..c.llm_vocab as usize]);
            generated.push(cur);
        }
        assert_eq!(generated.len(), 4);
        assert!(generated.iter().all(|&t| t >= 0 && t < c.llm_vocab as i32));
        assert_eq!(state.lens[0], len + 3);
    }

    #[test]
    fn decode_extract_roundtrip() {
        if !artifacts_available() {
            return;
        }
        let mut rt = TinyLmmRuntime::load("artifacts").unwrap();
        let kv_len = rt.kv_len();
        let kv_a: Vec<f32> = (0..kv_len).map(|i| (i % 97) as f32).collect();
        let kv_b: Vec<f32> = (0..kv_len).map(|i| (i % 89) as f32 * 0.5).collect();
        let state = rt.decode_start(&[&kv_a, &kv_b], &[10, 20]).unwrap();
        let out = rt.decode_extract(&state).unwrap();
        assert_eq!(out[0], kv_a);
        assert_eq!(out[1], kv_b);
    }

    #[test]
    fn argmax_basics() {
        assert_eq!(argmax(&[0.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
