//! Bayesian optimization driver (§3.2.3): random initial design, GP
//! surrogate on observed (config, objective) pairs, expected-improvement
//! acquisition maximized over a random candidate pool. Includes a pure
//! random-search mode (the Table 5 ablation's "w/o Opt." arm).

use crate::util::rng::Rng;

use super::gp::Gp;
use super::space::{ConfigPoint, SearchSpace};

/// Driver configuration.
#[derive(Debug, Clone, Copy)]
pub struct BayesOptConfig {
    /// Random-design evaluations before the GP takes over.
    pub init_samples: usize,
    /// Total evaluation budget.
    pub budget: usize,
    /// Candidate pool size per acquisition step.
    pub candidates: usize,
    pub seed: u64,
}

impl Default for BayesOptConfig {
    fn default() -> Self {
        BayesOptConfig { init_samples: 8, budget: 24, candidates: 256, seed: 7 }
    }
}

/// Result of an optimization run.
#[derive(Debug, Clone)]
pub struct OptResult {
    pub best: ConfigPoint,
    pub best_value: f64,
    /// All (point, value) evaluations in order.
    pub history: Vec<(ConfigPoint, f64)>,
}

/// Bayesian optimizer over a [`SearchSpace`].
pub struct BayesOpt {
    pub space: SearchSpace,
    pub cfg: BayesOptConfig,
}

impl BayesOpt {
    pub fn new(space: SearchSpace, cfg: BayesOptConfig) -> BayesOpt {
        BayesOpt { space, cfg }
    }

    /// Maximize `eval` with the GP + EI loop.
    pub fn run<F: FnMut(&ConfigPoint) -> f64>(&self, mut eval: F) -> OptResult {
        let mut rng = Rng::new(self.cfg.seed);
        let mut history: Vec<(ConfigPoint, f64)> = Vec::new();

        // Initial random design.
        for _ in 0..self.cfg.init_samples.min(self.cfg.budget) {
            let p = self.space.sample(&mut rng);
            let v = eval(&p);
            history.push((p, v));
        }

        // One GP reused across acquisition iterations: fresh evaluations
        // append through the O(n²) incremental Cholesky (`Gp::observe`)
        // instead of refitting the O(n³) factorization from scratch each
        // step. A full refit happens only when the observed variance
        // drifts more than 25% from the amplitude the factor was built
        // with, so the σ² hyperparameter still tracks the objective's
        // scale.
        let mut gp = Gp::new(2.0, 1.0, 1e-4);
        let mut fitted = 0usize;
        while history.len() < self.cfg.budget {
            let ys: Vec<f64> = history.iter().map(|(_, v)| *v).collect();
            let sv = variance(&ys).max(1e-3);
            if fitted == 0 || (sv - gp.signal_var()).abs() > 0.25 * gp.signal_var() {
                gp = Gp::new(2.0, sv, 1e-4);
                gp.fit(history.iter().map(|(p, _)| p.features()).collect(), &ys);
            } else {
                for (p, v) in &history[fitted..] {
                    gp.observe(p.features(), *v);
                }
            }
            fitted = history.len();
            let best = ys.iter().copied().fold(f64::NEG_INFINITY, f64::max);

            // Maximize EI over a random candidate pool. An empty pool
            // (`candidates == 0`) means there is nothing to acquire:
            // stop and report the best point observed so far.
            let mut best_cand: Option<(ConfigPoint, f64)> = None;
            for _ in 0..self.cfg.candidates {
                let c = self.space.sample(&mut rng);
                let ei = gp.expected_improvement(&c.features(), best);
                if best_cand.as_ref().map(|(_, b)| ei > *b).unwrap_or(true) {
                    best_cand = Some((c, ei));
                }
            }
            let Some((next, _)) = best_cand else { break };
            let v = eval(&next);
            history.push((next, v));
        }

        // A run that never evaluated anything (zero init samples and an
        // empty candidate pool) still returns a well-formed point: an
        // unevaluated sample, flagged by the -inf value.
        let (best, best_value) = history
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(p, v)| (p.clone(), *v))
            .unwrap_or_else(|| (self.space.sample(&mut rng), f64::NEG_INFINITY));
        OptResult { best, best_value, history }
    }

    /// Pure random search with the same budget (the ablation baseline: the
    /// paper samples 10 uniform configs and reports the expected metric).
    pub fn random_search<F: FnMut(&ConfigPoint) -> f64>(&self, mut eval: F) -> OptResult {
        let mut rng = Rng::new(self.cfg.seed ^ 0xDEAD_BEEF);
        let mut history = Vec::new();
        for _ in 0..self.cfg.budget {
            let p = self.space.sample(&mut rng);
            let v = eval(&p);
            history.push((p, v));
        }
        let (best, best_value) = history
            .iter()
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .map(|(p, v)| (p.clone(), *v))
            .unwrap();
        OptResult { best, best_value, history }
    }
}

fn variance(ys: &[f64]) -> f64 {
    if ys.len() < 2 {
        return 1.0;
    }
    let m = ys.iter().sum::<f64>() / ys.len() as f64;
    ys.iter().map(|y| (y - m) * (y - m)).sum::<f64>() / (ys.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic objective with a known optimum: prefer 5E / 2P / 1D and
    /// IRP on, penalize big encode batches.
    fn toy_objective(p: &ConfigPoint) -> f64 {
        let t = &p.topology;
        let topo_score = -((t.encode as f64 - 5.0).powi(2)
            + (t.prefill as f64 - 2.0).powi(2)
            + (t.decode as f64 - 1.0).powi(2));
        topo_score + if p.irp { 2.0 } else { 0.0 } - (p.batch_e as f64) * 0.1
    }

    #[test]
    fn bayes_beats_random_on_toy() {
        let space = SearchSpace::paper_default(8);
        let cfg = BayesOptConfig { init_samples: 6, budget: 20, candidates: 128, seed: 3 };
        let opt = BayesOpt::new(space, cfg);
        let bo = opt.run(toy_objective);
        // Small budget, easy space: BO should find a near-optimal topology.
        assert!(bo.best_value > -4.0, "bo best {}", bo.best_value);
        assert!(bo.best.irp, "IRP should be selected");
        assert_eq!(bo.history.len(), 20);
    }

    #[test]
    fn random_search_runs_budget() {
        let space = SearchSpace::paper_default(8);
        let opt = BayesOpt::new(space, BayesOptConfig { budget: 10, ..Default::default() });
        let rs = opt.random_search(toy_objective);
        assert_eq!(rs.history.len(), 10);
        assert!(rs.best_value >= rs.history[0].1);
    }

    #[test]
    fn empty_candidate_pool_returns_best_observed() {
        let space = SearchSpace::paper_default(8);
        let cfg = BayesOptConfig { init_samples: 5, budget: 20, candidates: 0, seed: 11 };
        let bo = BayesOpt::new(space, cfg).run(toy_objective);
        // Acquisition has nothing to rank: the run ends after the initial
        // design and reports its best point instead of panicking.
        assert_eq!(bo.history.len(), 5);
        let best_seen =
            bo.history.iter().map(|(_, v)| *v).fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(bo.best_value, best_seen);
    }

    #[test]
    fn zero_budget_run_is_well_formed() {
        let space = SearchSpace::paper_default(8);
        let cfg = BayesOptConfig { init_samples: 0, budget: 3, candidates: 0, seed: 2 };
        let bo = BayesOpt::new(space, cfg).run(toy_objective);
        assert!(bo.history.is_empty());
        assert_eq!(bo.best_value, f64::NEG_INFINITY, "nothing evaluated");
        assert!(bo.best.topology.total() > 0, "still a valid point");
    }

    #[test]
    fn deterministic_given_seed() {
        let space = SearchSpace::paper_default(8);
        let cfg = BayesOptConfig { init_samples: 4, budget: 10, candidates: 64, seed: 9 };
        let a = BayesOpt::new(space.clone(), cfg).run(toy_objective);
        let b = BayesOpt::new(space, cfg).run(toy_objective);
        assert_eq!(a.best_value, b.best_value);
        assert_eq!(a.best, b.best);
    }
}
