//! The configuration search space X of Appendix D.
//!
//! A point fixes: the topology (instances per stage, constrained to the
//! cluster's GPU count), per-stage max batch sizes, the queue/assignment
//! policies, and the IRP toggle. Appendix E.4's restricted space (TP = PP
//! = 1, uniform batch per stage) is the default; rejection sampling
//! enforces the total-GPU constraint exactly as described.

use crate::core::config::{AssignPolicy, EpdConfig, QueuePolicy};
use crate::core::stage::Stage;
use crate::core::topology::Topology;
use crate::util::rng::Rng;

/// All topologies reachable from `t` by at most `radius` single-instance
/// moves, with every stage kept at `floor` or more instances. Excludes
/// `t` itself. This is the move structure of the Appendix D space
/// restricted to the fixed cluster — the candidate set the online
/// reallocation planner scores, and the local neighborhood a hill-climb
/// over [`ConfigPoint`] topologies explores.
pub fn topology_neighborhood(t: Topology, radius: u32, floor: u32) -> Vec<Topology> {
    let mut seen = vec![t];
    let mut frontier = vec![t];
    for _ in 0..radius {
        let mut next = Vec::new();
        for &cur in &frontier {
            for from in Stage::ALL {
                if cur.count(from) <= floor {
                    continue;
                }
                for to in Stage::ALL {
                    if from == to {
                        continue;
                    }
                    let mut n = cur;
                    n.set_count(from, n.count(from) - 1);
                    n.set_count(to, n.count(to) + 1);
                    if !seen.contains(&n) {
                        seen.push(n);
                        next.push(n);
                    }
                }
            }
        }
        frontier = next;
    }
    seen.retain(|&x| x != t);
    seen
}

/// One candidate configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfigPoint {
    pub topology: Topology,
    pub batch_e: u32,
    pub batch_p: u32,
    pub batch_d: u32,
    pub queue: QueuePolicy,
    pub assign: AssignPolicy,
    pub irp: bool,
}

impl ConfigPoint {
    /// Materialize as an [`EpdConfig`].
    pub fn to_epd(&self) -> EpdConfig {
        let mut cfg = EpdConfig::epd(self.topology, self.batch_e, self.batch_p, self.batch_d);
        cfg.irp = self.irp;
        for s in [
            &mut cfg.sched_encode,
            &mut cfg.sched_prefill,
            &mut cfg.sched_decode,
        ] {
            s.queue = self.queue;
            s.assign = self.assign;
        }
        cfg
    }

    /// Encode as a numeric feature vector for the GP surrogate.
    pub fn features(&self) -> Vec<f64> {
        vec![
            self.topology.encode as f64,
            self.topology.prefill as f64,
            self.topology.decode as f64,
            (self.batch_e as f64).ln_1p(),
            (self.batch_p as f64).ln_1p(),
            (self.batch_d as f64).ln_1p(),
            match self.queue {
                QueuePolicy::Fcfs => 0.0,
                QueuePolicy::Sjf => 1.0,
                QueuePolicy::SloAware => 2.0,
                QueuePolicy::Priority => 3.0,
            },
            match self.assign {
                AssignPolicy::RoundRobin => 0.0,
                AssignPolicy::LeastLoaded => 1.0,
            },
            self.irp as u8 as f64,
        ]
    }
}

/// The search space.
#[derive(Debug, Clone)]
pub struct SearchSpace {
    /// Total GPUs that must be used exactly (Appendix D's implicit
    /// constraint for full utilization).
    pub total_gpus: u32,
    pub batch_choices: Vec<u32>,
    pub decode_batch_choices: Vec<u32>,
    pub queue_choices: Vec<QueuePolicy>,
    pub assign_choices: Vec<AssignPolicy>,
    pub allow_irp_toggle: bool,
}

impl SearchSpace {
    /// The Appendix E.4 space on `total_gpus` GPUs.
    pub fn paper_default(total_gpus: u32) -> SearchSpace {
        SearchSpace {
            total_gpus,
            batch_choices: vec![1, 2, 4, 8],
            decode_batch_choices: vec![16, 32, 64, 128],
            queue_choices: vec![QueuePolicy::Fcfs, QueuePolicy::Sjf],
            assign_choices: vec![AssignPolicy::RoundRobin, AssignPolicy::LeastLoaded],
            allow_irp_toggle: true,
        }
    }

    /// Sample a valid point uniformly (rejection sampling over topologies).
    pub fn sample(&self, rng: &mut Rng) -> ConfigPoint {
        let topology = loop {
            let e = rng.range(1, self.total_gpus as usize - 2) as u32;
            let p = rng.range(1, self.total_gpus as usize - 2) as u32;
            let d = self.total_gpus as i64 - e as i64 - p as i64;
            if d >= 1 {
                break Topology::new(e, p, d as u32);
            }
        };
        ConfigPoint {
            topology,
            batch_e: *rng.choose(&self.batch_choices),
            batch_p: *rng.choose(&self.batch_choices),
            batch_d: *rng.choose(&self.decode_batch_choices),
            queue: *rng.choose(&self.queue_choices),
            assign: *rng.choose(&self.assign_choices),
            irp: if self.allow_irp_toggle { rng.bool(0.5) } else { true },
        }
    }

    /// The exhaustive-sweep grid: one default-policy candidate per
    /// topology (batch E1/P1/D128, FCFS, least-loaded, IRP on — the EPD
    /// defaults). This is the candidate set `optimize --sweep` fans out
    /// across threads via `ConfigEvaluator::goodput_many`.
    pub fn topology_grid(&self) -> Vec<ConfigPoint> {
        self.topologies()
            .into_iter()
            .map(|topology| ConfigPoint {
                topology,
                batch_e: 1,
                batch_p: 1,
                batch_d: 128,
                queue: QueuePolicy::Fcfs,
                assign: AssignPolicy::LeastLoaded,
                irp: true,
            })
            .collect()
    }

    /// Enumerate all topologies summing to the GPU budget (used by the
    /// exhaustive mode of small sweeps, e.g. Figure 10-left).
    pub fn topologies(&self) -> Vec<Topology> {
        let n = self.total_gpus;
        let mut out = Vec::new();
        for e in 1..=(n - 2) {
            for p in 1..=(n - 1 - e) {
                let d = n - e - p;
                if d >= 1 {
                    out.push(Topology::new(e, p, d));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_respect_gpu_budget() {
        let space = SearchSpace::paper_default(8);
        let mut rng = Rng::new(11);
        for _ in 0..500 {
            let p = space.sample(&mut rng);
            assert_eq!(p.topology.total(), 8);
            assert!(p.topology.encode >= 1 && p.topology.prefill >= 1 && p.topology.decode >= 1);
            assert!(space.batch_choices.contains(&p.batch_e));
            assert!(space.decode_batch_choices.contains(&p.batch_d));
        }
    }

    #[test]
    fn enumeration_complete_for_8_gpus() {
        let space = SearchSpace::paper_default(8);
        let topos = space.topologies();
        // Compositions of 8 into 3 positive parts: C(7,2) = 21.
        assert_eq!(topos.len(), 21);
        assert!(topos.contains(&Topology::new(5, 2, 1)));
        assert!(topos.iter().all(|t| t.total() == 8));
    }

    #[test]
    fn features_are_stable_length() {
        let space = SearchSpace::paper_default(8);
        let mut rng = Rng::new(12);
        let a = space.sample(&mut rng).features();
        let b = space.sample(&mut rng).features();
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), 9);
    }

    #[test]
    fn neighborhood_conserves_total_and_floor() {
        let t = Topology::new(2, 2, 1);
        let n1 = topology_neighborhood(t, 1, 1);
        // Radius 1 from (2,2,1) with floor 1: donors E and P (D is at the
        // floor), two destinations each = 4 distinct candidates.
        assert_eq!(n1.len(), 4);
        for c in &n1 {
            assert_eq!(c.total(), t.total());
            for s in Stage::ALL {
                assert!(c.count(s) >= 1);
            }
            assert_ne!(*c, t);
        }
        let n2 = topology_neighborhood(t, 2, 1);
        assert!(n2.len() > n1.len(), "radius grows the candidate set");
        assert!(n2.contains(&Topology::new(1, 1, 3)), "two moves reach 1E1P3D");
        // Floor 0 additionally allows draining a stage entirely; floor 1
        // never does.
        assert!(topology_neighborhood(t, 1, 0).contains(&Topology::new(3, 2, 0)));
        assert!(!n1.contains(&Topology::new(3, 2, 0)));
    }

    #[test]
    fn topology_grid_covers_every_topology_with_defaults() {
        let space = SearchSpace::paper_default(8);
        let grid = space.topology_grid();
        assert_eq!(grid.len(), space.topologies().len());
        for p in &grid {
            assert_eq!(p.topology.total(), 8);
            assert_eq!((p.batch_e, p.batch_p, p.batch_d), (1, 1, 128));
            assert!(p.irp);
        }
    }

    #[test]
    fn to_epd_roundtrip() {
        let space = SearchSpace::paper_default(8);
        let mut rng = Rng::new(13);
        let p = space.sample(&mut rng);
        let cfg = p.to_epd();
        assert_eq!(cfg.topology(), p.topology);
        assert_eq!(cfg.irp, p.irp);
    }
}
