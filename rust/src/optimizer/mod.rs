//! The black-box resource-allocation optimizer (§3.2.3, Appendix D):
//! maximize `f(p, b, s) − β·cost(p)` over parallelization, batch-size and
//! scheduling configurations, evaluating `f` with the simulator.
//!
//! [`bayes`] implements Bayesian optimization with a Gaussian-process
//! surrogate ([`gp`]) and expected improvement; [`space`] defines the
//! discrete configuration space with the paper's implicit constraints
//! (total GPUs fixed, ≥1 instance per needed stage).
//!
//! The same GP machinery also powers the *online* planner: [`surrogate`]
//! maintains an incrementally trained model over (workload profile,
//! topology) features that prefilters reallocation candidates, and
//! [`whatif`] evaluates the survivors with short pooled simulations
//! seeded from the live profile.

pub mod space;
pub mod gp;
pub mod bayes;
pub mod objective;
pub mod surrogate;
pub mod whatif;

pub use bayes::{BayesOpt, BayesOptConfig};
pub use objective::{ConfigEvaluator, Objective};
pub use space::{topology_neighborhood, ConfigPoint, SearchSpace};
pub use surrogate::{planner_features, Selection, SurrogateModel};
pub use whatif::WhatIfEvaluator;
