//! A small Gaussian-process regressor (RBF kernel, Cholesky solve) — the
//! surrogate for Bayesian optimization over the config space and the
//! online planner's candidate prefilter. Batch refits pay the O(n³)
//! factorization; [`Gp::observe`] grows the same factor one rank-1 row at
//! a time for O(n²) per observation, bit-for-bit identical to a batch
//! refit on the same data.

/// GP with RBF kernel k(x,x') = σ²·exp(−‖x−x'‖²/(2ℓ²)) + noise·δ.
#[derive(Debug, Clone)]
pub struct Gp {
    lengthscale: f64,
    signal_var: f64,
    noise_var: f64,
    xs: Vec<Vec<f64>>,
    /// Observed targets, kept so incremental appends can re-center.
    ys: Vec<f64>,
    /// Cholesky factor L of K (lower triangular, row-major packed).
    chol: Vec<Vec<f64>>,
    /// α = K⁻¹ y.
    alpha: Vec<f64>,
    y_mean: f64,
}

impl Gp {
    pub fn new(lengthscale: f64, signal_var: f64, noise_var: f64) -> Gp {
        assert!(lengthscale > 0.0 && signal_var > 0.0 && noise_var >= 0.0);
        Gp {
            lengthscale,
            signal_var,
            noise_var,
            xs: Vec::new(),
            ys: Vec::new(),
            chol: Vec::new(),
            alpha: Vec::new(),
            y_mean: 0.0,
        }
    }

    /// Observations currently in the model.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// The prior (signal) variance σ² — what an empty GP predicts.
    pub fn signal_var(&self) -> f64 {
        self.signal_var
    }

    fn kernel(&self, a: &[f64], b: &[f64]) -> f64 {
        let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        self.signal_var * (-d2 / (2.0 * self.lengthscale * self.lengthscale)).exp()
    }

    /// Fit to observations (replaces previous fit).
    pub fn fit(&mut self, xs: Vec<Vec<f64>>, ys: &[f64]) {
        assert_eq!(xs.len(), ys.len());
        let n = xs.len();

        // Build K + noise I.
        let mut k = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..=i {
                let v = self.kernel(&xs[i], &xs[j]);
                k[i][j] = v;
                k[j][i] = v;
            }
            k[i][i] += self.noise_var + 1e-9;
        }
        // Cholesky K = L Lᵀ.
        let mut l = vec![vec![0.0; n]; n];
        for i in 0..n {
            for j in 0..=i {
                let mut s = k[i][j];
                for t in 0..j {
                    s -= l[i][t] * l[j][t];
                }
                if i == j {
                    l[i][j] = s.max(1e-12).sqrt();
                } else {
                    l[i][j] = s / l[j][j];
                }
            }
        }
        self.xs = xs;
        self.ys = ys.to_vec();
        self.chol = l;
        self.refresh_alpha();
    }

    /// Append one observation with a rank-1 Cholesky update: the new row
    /// of L costs O(n²) (vs the O(n³) refactorization [`Self::fit`]
    /// pays) and is arithmetic-for-arithmetic the row `fit` would have
    /// produced, so an incrementally grown GP predicts bit-for-bit
    /// identically to a batch refit on the same data (property-tested in
    /// `rust/tests/property_surrogate.rs`).
    pub fn observe(&mut self, x: Vec<f64>, y: f64) {
        let n = self.xs.len();
        let mut row = vec![0.0; n + 1];
        for j in 0..n {
            let mut s = self.kernel(&x, &self.xs[j]);
            for t in 0..j {
                s -= row[t] * self.chol[j][t];
            }
            row[j] = s / self.chol[j][j];
        }
        let mut s = self.kernel(&x, &x);
        s += self.noise_var + 1e-9;
        for t in 0..n {
            s -= row[t] * row[t];
        }
        row[n] = s.max(1e-12).sqrt();
        self.xs.push(x);
        self.ys.push(y);
        self.chol.push(row);
        // α and the centered targets depend on every y through the mean:
        // re-solve the two triangular systems (O(n²)) from the stored ys.
        self.refresh_alpha();
    }

    /// Recompute the mean-centering and α = K⁻¹(y − ȳ) from the current
    /// factor — the O(n²) tail shared by `fit` and `observe`. Same
    /// arithmetic (and therefore the same bits) as the historical inline
    /// solves in `fit`.
    fn refresh_alpha(&mut self) {
        let n = self.xs.len();
        self.y_mean = if n == 0 { 0.0 } else { self.ys.iter().sum::<f64>() / n as f64 };
        // Solve L z = y − ȳ, then Lᵀ α = z.
        let mut z = vec![0.0; n];
        for i in 0..n {
            let mut s = self.ys[i] - self.y_mean;
            for t in 0..i {
                s -= self.chol[i][t] * z[t];
            }
            z[i] = s / self.chol[i][i];
        }
        let mut alpha = vec![0.0; n];
        for i in (0..n).rev() {
            let mut s = z[i];
            for t in i + 1..n {
                s -= self.chol[t][i] * alpha[t];
            }
            alpha[i] = s / self.chol[i][i];
        }
        self.alpha = alpha;
    }

    /// Posterior mean and variance at `x`.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let n = self.xs.len();
        if n == 0 {
            return (self.y_mean, self.signal_var);
        }
        let kstar: Vec<f64> = self.xs.iter().map(|xi| self.kernel(xi, x)).collect();
        let mean = self.y_mean + kstar.iter().zip(&self.alpha).map(|(k, a)| k * a).sum::<f64>();
        // v = L⁻¹ k*.
        let mut v = vec![0.0; n];
        for i in 0..n {
            let mut s = kstar[i];
            for t in 0..i {
                s -= self.chol[i][t] * v[t];
            }
            v[i] = s / self.chol[i][i];
        }
        let var = (self.signal_var - v.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        (mean, var)
    }

    /// Expected improvement over `best` (maximization).
    pub fn expected_improvement(&self, x: &[f64], best: f64) -> f64 {
        let (mu, var) = self.predict(x);
        let sigma = var.sqrt();
        if sigma < 1e-12 {
            return (mu - best).max(0.0);
        }
        let z = (mu - best) / sigma;
        (mu - best) * norm_cdf(z) + sigma * norm_pdf(z)
    }
}

fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Abramowitz–Stegun style erf approximation (max abs error ~1.5e-7).
fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolates_training_points() {
        let mut gp = Gp::new(1.0, 1.0, 1e-6);
        let xs = vec![vec![0.0], vec![1.0], vec![2.0]];
        let ys = [0.0, 1.0, 0.0];
        gp.fit(xs.clone(), &ys);
        for (x, y) in xs.iter().zip(ys) {
            let (mu, var) = gp.predict(x);
            assert!((mu - y).abs() < 1e-2, "mu {mu} vs {y}");
            assert!(var < 0.01);
        }
    }

    #[test]
    fn uncertainty_grows_away_from_data() {
        let mut gp = Gp::new(0.5, 1.0, 1e-6);
        gp.fit(vec![vec![0.0]], &[1.0]);
        let (_, v_near) = gp.predict(&[0.1]);
        let (_, v_far) = gp.predict(&[5.0]);
        assert!(v_far > 10.0 * v_near);
    }

    #[test]
    fn ei_positive_in_unexplored_regions() {
        let mut gp = Gp::new(0.5, 1.0, 1e-6);
        gp.fit(vec![vec![0.0], vec![1.0]], &[0.0, 0.5]);
        let ei_far = gp.expected_improvement(&[3.0], 0.5);
        let ei_known_bad = gp.expected_improvement(&[0.0], 0.5);
        assert!(ei_far > ei_known_bad);
    }

    #[test]
    fn erf_sanity() {
        assert!((erf(0.0)).abs() < 1e-7); // A&S 7.1.26 max error ~1.5e-7
        assert!((erf(1.0) - 0.8427).abs() < 1e-3);
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-9);
        assert!(norm_cdf(3.0) > 0.998);
    }

    #[test]
    fn empty_gp_predicts_prior() {
        let gp = Gp::new(1.0, 2.0, 1e-6);
        let (mu, var) = gp.predict(&[1.0]);
        assert_eq!(mu, 0.0);
        assert_eq!(var, 2.0);
    }

    /// Deterministic pseudo-random doubles in [0, 1) for the equivalence
    /// tests (xorshift; no RNG dependency inside the optimizer crate).
    fn prand(state: &mut u64) -> f64 {
        *state ^= *state << 13;
        *state ^= *state >> 7;
        *state ^= *state << 17;
        (*state >> 11) as f64 / (1u64 << 53) as f64
    }

    #[test]
    fn incremental_observe_matches_batch_fit_bitwise() {
        let mut s = 0x9E37_79B9_7F4A_7C15u64;
        let xs: Vec<Vec<f64>> =
            (0..12).map(|_| (0..3).map(|_| prand(&mut s) * 4.0).collect()).collect();
        let ys: Vec<f64> = (0..12).map(|_| prand(&mut s) * 2.0 - 1.0).collect();

        let mut batch = Gp::new(1.5, 0.8, 1e-4);
        batch.fit(xs.clone(), &ys);
        let mut inc = Gp::new(1.5, 0.8, 1e-4);
        for (x, y) in xs.iter().zip(&ys) {
            inc.observe(x.clone(), *y);
        }
        assert_eq!(inc.len(), batch.len());

        for _ in 0..20 {
            let probe: Vec<f64> = (0..3).map(|_| prand(&mut s) * 5.0 - 0.5).collect();
            let (mb, vb) = batch.predict(&probe);
            let (mi, vi) = inc.predict(&probe);
            assert_eq!(mb.to_bits(), mi.to_bits(), "posterior mean must match bitwise");
            assert_eq!(vb.to_bits(), vi.to_bits(), "posterior variance must match bitwise");
            let eb = batch.expected_improvement(&probe, 0.3);
            let ei = inc.expected_improvement(&probe, 0.3);
            assert_eq!(eb.to_bits(), ei.to_bits(), "EI must match bitwise");
        }
    }

    #[test]
    fn observe_extends_an_existing_fit() {
        let mut gp = Gp::new(1.0, 1.0, 1e-6);
        gp.fit(vec![vec![0.0], vec![1.0]], &[0.0, 1.0]);
        gp.observe(vec![2.0], 0.0);
        assert_eq!(gp.len(), 3);
        let mut batch = Gp::new(1.0, 1.0, 1e-6);
        batch.fit(vec![vec![0.0], vec![1.0], vec![2.0]], &[0.0, 1.0, 0.0]);
        let (m_inc, v_inc) = gp.predict(&[1.5]);
        let (m_b, v_b) = batch.predict(&[1.5]);
        assert_eq!(m_inc.to_bits(), m_b.to_bits());
        assert_eq!(v_inc.to_bits(), v_b.to_bits());
        // The appended point interpolates like any fitted one.
        let (mu, var) = gp.predict(&[2.0]);
        assert!((mu - 0.0).abs() < 1e-2, "mu {mu}");
        assert!(var < 0.01);
    }
}
