//! Tier 2 of the two-tier candidate evaluation path: short-horizon
//! what-if simulation seeded from the live workload profile.
//!
//! `ConfigEvaluator` answers "what is this config's goodput" by binary-
//! searching full workload replays — thousands of requests per candidate,
//! far too slow for a planning pass. The [`WhatIfEvaluator`] answers the
//! planner's much narrower question — "how would the *current* workload
//! fare on this topology over the next few seconds" — with a simulation
//! short enough to run per candidate per tick:
//!
//! - The synthetic workload is generated from the profiler's EWMAs
//!   (arrival rate, images/prompt/output shape) plus a backlog prelude
//!   standing in for the work already queued.
//! - Every candidate in a planning pass sees the *identical* workload
//!   (common random numbers: one fixed seed), so candidate comparisons
//!   cancel the sampling noise instead of chasing it.
//! - Runs go through [`Simulator::run_pooled`] with a resident
//!   [`SimPool`]: the event heap, request slab and scratch buffers are
//!   recycled across evaluations instead of reallocated per run, and
//!   timelines stay off so metrics accumulate in O(1) memory.

use crate::coordinator::profiler::WorkloadProfile;
use crate::core::config::{EpdConfig, PlannerPolicy, RouterPolicy};
use crate::core::request::Request;
use crate::core::stage::Stage;
use crate::core::topology::Topology;
use crate::model::spec::{DeviceSpec, LmmSpec};
use crate::model::vision::Resolution;
use crate::sim::engine::{SimConfig, SimPool, Simulator};
use crate::util::rng::Rng;
use crate::workload::build_request;

/// Fixed workload seed: common random numbers across every candidate (and
/// every planning pass), so what-if scores are comparable and replayable.
const WHATIF_SEED: u64 = 0x57A7_1C5E;

/// Most synthetic requests per evaluation (arrivals + backlog prelude):
/// keeps the worst-case cost of one honest evaluation bounded no matter
/// how hot the profile runs.
const MAX_ARRIVALS: usize = 48;
const MAX_BACKLOG: usize = 24;

/// Short-horizon candidate evaluator. Scores are mean end-to-end latency
/// in seconds (lower is better) with shed/starved work penalized, so a
/// candidate can never look good by dropping requests.
#[derive(Debug, Clone)]
pub struct WhatIfEvaluator {
    spec: LmmSpec,
    device: DeviceSpec,
    /// The live config with every control loop forced off (role
    /// switching, faults, router): a what-if run measures the candidate
    /// topology, not the controllers layered on top of it.
    template: EpdConfig,
    /// Seconds of synthetic arrivals per evaluation.
    pub horizon: f64,
    pool: SimPool,
    evals: u64,
}

impl WhatIfEvaluator {
    pub fn new(spec: LmmSpec, device: DeviceSpec, epd: &EpdConfig) -> WhatIfEvaluator {
        let mut template = epd.clone();
        template.role_switching = false;
        template.planner = PlannerPolicy::Greedy;
        template.plan_interval = 0.0;
        template.router = RouterPolicy::Off;
        template.fault_seed = 0;
        WhatIfEvaluator {
            spec,
            device,
            template,
            horizon: epd.whatif_horizon.max(0.5),
            pool: SimPool::default(),
            evals: 0,
        }
    }

    /// Honest evaluations run so far (feeds the planner's stats).
    pub fn evals(&self) -> u64 {
        self.evals
    }

    /// The template with its instance list rebuilt for `cand`, keeping
    /// the live per-stage batch sizes.
    fn candidate_config(&self, cand: Topology) -> EpdConfig {
        let batch = |role: Stage| {
            self.template
                .instances
                .iter()
                .find(|i| i.role == role)
                .map(|i| i.max_batch)
                .unwrap_or(1)
        };
        let mut cfg = self.template.clone();
        cfg.instances = EpdConfig::epd(
            cand,
            batch(Stage::Encode),
            batch(Stage::Prefill),
            batch(Stage::Decode),
        )
        .instances;
        cfg
    }

    /// Synthesize the planning horizon's workload from the profile: a
    /// t = 0 prelude standing in for queued backlog, then Poisson
    /// arrivals at the profiled rate with the profiled request shape.
    fn synth_requests(&self, profile: &WorkloadProfile) -> Vec<Request> {
        let rate = profile.arrival_rate;
        let queued: f64 = profile.queue_len.iter().sum();
        if rate <= 1e-9 && queued < 0.5 {
            return Vec::new();
        }
        let n_backlog = (queued.round().max(0.0) as usize).min(MAX_BACKLOG);
        let n_arrive = if rate <= 1e-9 {
            0
        } else {
            ((rate * self.horizon).ceil() as usize).clamp(2, MAX_ARRIVALS)
        };
        let images = profile.images_per_request.round().max(0.0) as u32;
        let prompt = profile.prompt_tokens.round().max(1.0) as u32;
        let output = profile.output_tokens.round().max(1.0) as u32;
        let mut rng = Rng::new(WHATIF_SEED);
        let mut out = Vec::with_capacity(n_backlog + n_arrive);
        for i in 0..n_backlog {
            out.push(build_request(&self.spec, i as u64, 0.0, prompt, images, Resolution::four_k(), output));
        }
        let mut t = 0.0;
        for i in 0..n_arrive {
            t += rng.exp(rate);
            out.push(build_request(
                &self.spec,
                (n_backlog + i) as u64,
                t,
                prompt,
                images,
                Resolution::four_k(),
                output,
            ));
        }
        out
    }

    /// Score `cand` under the profiled workload: mean end-to-end latency
    /// plus a penalty per request the candidate failed to finish within
    /// the run (shed, or starved on an instance-less stage). Lower is
    /// better; an idle profile scores 0 for every candidate.
    pub fn score(&mut self, profile: &WorkloadProfile, cand: Topology) -> f64 {
        let requests = self.synth_requests(profile);
        if requests.is_empty() {
            return 0.0;
        }
        let mut cfg = SimConfig::new(self.spec.clone(), self.device, self.candidate_config(cand));
        cfg.record_timelines = false;
        let out = Simulator::run_pooled(&cfg, &requests, &mut self.pool);
        self.evals += 1;
        let n = requests.len() as f64;
        let missing = n - out.streamed.finished as f64;
        out.mean_latency() + missing.max(0.0) * (4.0 * self.horizon) / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    fn evaluator() -> WhatIfEvaluator {
        let epd = EpdConfig::epd(Topology::new(2, 2, 1), 1, 1, 2);
        WhatIfEvaluator::new(LmmSpec::get(ModelId::MiniCpmV26), DeviceSpec::a100(), &epd)
    }

    fn pressured_profile() -> WorkloadProfile {
        WorkloadProfile {
            arrival_rate: 2.5,
            images_per_request: 0.0,
            prompt_tokens: 64.0,
            output_tokens: 160.0,
            mm_tokens: 0.0,
            service: [0.0, 0.1, 0.5],
            queue_len: [0.0, 0.5, 12.0],
            backlog: [0.0, 0.3, 30.0],
            utilization: [0.05, 0.2, 1.0],
            instances: [2, 2, 1],
        }
    }

    #[test]
    fn idle_profile_scores_zero() {
        let mut ev = evaluator();
        let idle = WorkloadProfile {
            arrival_rate: 0.0,
            queue_len: [0.0; 3],
            ..pressured_profile()
        };
        assert_eq!(ev.score(&idle, Topology::new(2, 2, 1)), 0.0);
        assert_eq!(ev.evals(), 0, "idle scoring runs no simulation");
    }

    #[test]
    fn scores_are_deterministic_and_favor_the_relieving_topology() {
        let mut ev = evaluator();
        let prof = pressured_profile();
        let cur = ev.score(&prof, Topology::new(2, 2, 1));
        let cur2 = ev.score(&prof, Topology::new(2, 2, 1));
        assert_eq!(cur.to_bits(), cur2.to_bits(), "common random numbers: replayable");
        let shifted = ev.score(&prof, Topology::new(1, 1, 3));
        assert!(
            shifted < cur,
            "decode-starved profile must prefer decode capacity: {shifted} vs {cur}"
        );
        assert_eq!(ev.evals(), 3);
    }

    #[test]
    fn template_disables_every_control_loop() {
        let mut epd = EpdConfig::epd(Topology::new(2, 2, 1), 1, 1, 2);
        epd.role_switching = true;
        epd.planner = PlannerPolicy::Surrogate;
        epd.router = RouterPolicy::On;
        epd.fault_seed = 9;
        let ev = WhatIfEvaluator::new(LmmSpec::get(ModelId::MiniCpmV26), DeviceSpec::a100(), &epd);
        assert!(!ev.template.role_switching, "no nested planning");
        assert_eq!(ev.template.planner, PlannerPolicy::Greedy);
        assert_eq!(ev.template.router, RouterPolicy::Off);
        assert_eq!(ev.template.fault_seed, 0);
    }
}
