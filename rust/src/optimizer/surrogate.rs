//! Tier 1 of the two-tier candidate evaluation path: an online GP
//! surrogate over (workload profile, candidate topology) features.
//!
//! The reallocation planner's honest candidate evaluation — a short
//! what-if simulation per topology ([`super::whatif::WhatIfEvaluator`]) —
//! costs milliseconds; `Gp::predict` costs microseconds. The
//! [`SurrogateModel`] therefore scores the *whole* topology neighborhood
//! through the GP each planning pass and forwards only the EI-ranked
//! top-k to real evaluation. Every honest evaluation the system ever
//! runs is fed back through [`SurrogateModel::observe`] (the O(n²)
//! incremental Cholesky append), so the surrogate sharpens for free as
//! the planner works.
//!
//! An uncertainty floor keeps the prefilter honest under drift: a
//! candidate whose posterior variance exceeds `min_var` lies outside the
//! training support (the profile moved, or the topology was never
//! tried), and jumps the EI queue so the model re-anchors instead of
//! extrapolating.

use crate::coordinator::profiler::WorkloadProfile;
use crate::core::topology::Topology;

use super::gp::Gp;

/// Observations kept before the training window is compacted: the GP
/// solve is O(n²) per append, so an unbounded window would make planning
/// cost grow with uptime. At the cap the model refits on the most recent
/// half — recency matters more than ancient profiles anyway.
const MAX_OBSERVATIONS: usize = 256;

/// Feature vector for one (profile, candidate topology) pair — the
/// planner-side analogue of `ConfigPoint::features`. Token counts are
/// scaled and backlogs log-compressed so no single dimension dwarfs the
/// RBF distance.
pub fn planner_features(profile: &WorkloadProfile, cand: Topology) -> Vec<f64> {
    vec![
        profile.arrival_rate,
        profile.images_per_request,
        profile.prompt_tokens / 64.0,
        profile.output_tokens / 64.0,
        profile.backlog[0].max(0.0).ln_1p(),
        profile.backlog[1].max(0.0).ln_1p(),
        profile.backlog[2].max(0.0).ln_1p(),
        profile.utilization[0],
        profile.utilization[1],
        profile.utilization[2],
        cand.encode as f64,
        cand.prefill as f64,
        cand.decode as f64,
    ]
}

/// Indices chosen by [`SurrogateModel::select`], plus how many of them
/// were forced through by the uncertainty floor rather than EI rank.
#[derive(Debug, Clone, Default)]
pub struct Selection {
    /// Candidate indices to evaluate honestly, best-ranked first.
    pub chosen: Vec<usize>,
    /// How many of `chosen` exceeded the posterior-variance floor.
    pub forced: u64,
}

/// The online GP surrogate: trains incrementally on observed
/// (features → objective) pairs and EI-ranks candidate pools. Objectives
/// are on a maximization scale — the planner feeds negated what-if
/// scores.
#[derive(Debug, Clone)]
pub struct SurrogateModel {
    gp: Gp,
    /// Recent training window, kept verbatim so the compaction refit can
    /// rebuild the factor from scratch.
    window: Vec<(Vec<f64>, f64)>,
    /// Best objective observed so far (the EI anchor).
    best_y: f64,
    observations: u64,
}

impl SurrogateModel {
    pub fn new(lengthscale: f64) -> SurrogateModel {
        SurrogateModel {
            gp: Gp::new(lengthscale, 1.0, 1e-4),
            window: Vec::new(),
            best_y: f64::NEG_INFINITY,
            observations: 0,
        }
    }

    /// Total observations ever fed in (not capped by the window).
    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// Posterior (mean, variance) at `features`.
    pub fn predict(&self, features: &[f64]) -> (f64, f64) {
        self.gp.predict(features)
    }

    /// Feed one honest evaluation back into the model.
    pub fn observe(&mut self, features: Vec<f64>, y: f64) {
        if y > self.best_y {
            self.best_y = y;
        }
        self.observations += 1;
        if self.window.len() >= MAX_OBSERVATIONS {
            // Compact: refit on the most recent half. One O(k³) refit
            // per k/2 appends keeps amortized planning cost flat.
            self.window.drain(..MAX_OBSERVATIONS / 2);
            self.window.push((features, y));
            let xs: Vec<Vec<f64>> = self.window.iter().map(|(x, _)| x.clone()).collect();
            let ys: Vec<f64> = self.window.iter().map(|(_, v)| *v).collect();
            self.gp.fit(xs, &ys);
        } else {
            self.window.push((features.clone(), y));
            self.gp.observe(features, y);
        }
    }

    /// EI-rank a candidate pool and return the top-k to evaluate
    /// honestly. Candidates whose posterior variance exceeds `min_var`
    /// are outside training support and are forced ahead of the EI
    /// ranking (the exploration floor); ties break on pool order so the
    /// selection is deterministic.
    pub fn select(&self, feats: &[Vec<f64>], topk: usize, min_var: f64) -> Selection {
        let k = topk.max(1).min(feats.len());
        if self.gp.is_empty() {
            // Untrained model: everything is unexplored. Take the pool
            // head (deterministic) and flag it all as forced.
            return Selection { chosen: (0..k).collect(), forced: k as u64 };
        }
        let mut ranked: Vec<(usize, bool, f64)> = feats
            .iter()
            .enumerate()
            .map(|(i, f)| {
                let (_, var) = self.gp.predict(f);
                let ei = self.gp.expected_improvement(f, self.best_y);
                (i, var > min_var, ei)
            })
            .collect();
        ranked.sort_by(|a, b| {
            b.1.cmp(&a.1)
                .then(b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal))
                .then(a.0.cmp(&b.0))
        });
        let chosen: Vec<usize> = ranked.iter().take(k).map(|r| r.0).collect();
        let forced = ranked.iter().take(k).filter(|r| r.1).count() as u64;
        Selection { chosen, forced }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> WorkloadProfile {
        WorkloadProfile {
            arrival_rate: 2.5,
            images_per_request: 2.0,
            prompt_tokens: 64.0,
            output_tokens: 160.0,
            mm_tokens: 2560.0,
            service: [0.1, 0.2, 0.4],
            queue_len: [0.0, 0.5, 12.0],
            backlog: [0.0, 0.3, 30.0],
            utilization: [0.05, 0.2, 1.0],
            instances: [2, 2, 1],
        }
    }

    #[test]
    fn features_distinguish_candidates_and_profiles() {
        let p = profile();
        let a = planner_features(&p, Topology::new(2, 2, 1));
        let b = planner_features(&p, Topology::new(1, 1, 3));
        assert_eq!(a.len(), b.len());
        assert_ne!(a, b, "topology dims must differ");
        let mut drifted = p;
        drifted.arrival_rate = 9.0;
        assert_ne!(a, planner_features(&drifted, Topology::new(2, 2, 1)));
    }

    #[test]
    fn untrained_model_forces_pool_head() {
        let m = SurrogateModel::new(2.0);
        let feats = vec![vec![0.0], vec![1.0], vec![2.0]];
        let sel = m.select(&feats, 2, 0.25);
        assert_eq!(sel.chosen, vec![0, 1]);
        assert_eq!(sel.forced, 2);
    }

    #[test]
    fn trained_model_prefers_the_known_optimum_region() {
        let mut m = SurrogateModel::new(1.0);
        // y peaks at x = 2.
        for (x, y) in [(0.0, 0.0), (1.0, 0.5), (2.0, 1.0), (3.0, 0.5), (4.0, 0.0)] {
            m.observe(vec![x], y);
        }
        assert_eq!(m.observations(), 5);
        // Tight pool near training data: EI must rank the point closest
        // to the optimum first (none exceed the variance floor).
        let feats = vec![vec![0.1], vec![2.1], vec![3.9]];
        let sel = m.select(&feats, 1, 10.0);
        assert_eq!(sel.chosen, vec![1]);
        assert_eq!(sel.forced, 0);
    }

    #[test]
    fn uncertainty_floor_forces_out_of_support_candidates() {
        let mut m = SurrogateModel::new(0.5);
        for (x, y) in [(0.0, 0.8), (0.5, 1.0), (1.0, 0.9)] {
            m.observe(vec![x], y);
        }
        // x = 50 is far outside support: high variance forces it in
        // ahead of near-data candidates even though its EI is not top.
        let feats = vec![vec![0.4], vec![50.0]];
        let sel = m.select(&feats, 1, 0.25);
        assert_eq!(sel.chosen, vec![1], "out-of-support candidate jumps the queue");
        assert_eq!(sel.forced, 1);
    }

    #[test]
    fn window_compaction_keeps_the_model_bounded() {
        let mut m = SurrogateModel::new(2.0);
        for i in 0..(MAX_OBSERVATIONS + 40) {
            let x = (i % 37) as f64 * 0.1;
            m.observe(vec![x], (x - 1.5).abs());
        }
        assert_eq!(m.observations() as usize, MAX_OBSERVATIONS + 40);
        assert!(m.window.len() <= MAX_OBSERVATIONS, "window stays capped");
        // Still predicts something sane after compaction.
        let (mu, var) = m.predict(&[1.5]);
        assert!(mu.is_finite() && var.is_finite());
    }
}
