//! The optimization objective (Eq. 1): `f(p, b, s) − β·cost(p)` where `f`
//! is goodput measured by the simulator and `cost(p)` is the GPU count
//! (constant per-GPU price `c`). With the fixed-cluster constraint the
//! cost term is constant, making the objective pure goodput — exactly the
//! Appendix E.4 setting — but β and variable-GPU spaces are supported.

use crate::core::slo::Slo;
use crate::model::spec::{DeviceSpec, LmmSpec};
use crate::metrics::goodput::find_goodput;
use crate::sim::engine::{SimConfig, Simulator};
use crate::util::rng::Rng;
use crate::workload::Workload;

use super::space::ConfigPoint;

/// Objective definition.
#[derive(Debug, Clone, Copy)]
pub struct Objective {
    /// GPU-count penalty weight β.
    pub beta: f64,
    /// Per-GPU unit cost c.
    pub gpu_cost: f64,
    /// SLO used for goodput.
    pub slo: Slo,
    /// Attainment threshold (the paper uses 0.9).
    pub threshold: f64,
}

/// Evaluates configurations through the simulator (the black-box `f`).
///
/// Evaluations are pure functions of `(point, seed)` — the simulator is
/// deterministic — so candidate sets can be fanned out across threads
/// ([`ConfigEvaluator::goodput_many`]) with bit-identical results at any
/// thread count.
pub struct ConfigEvaluator<'w> {
    pub spec: LmmSpec,
    pub device: DeviceSpec,
    pub workload: &'w (dyn Workload + Sync),
    pub objective: Objective,
    /// Requests per evaluation run (the paper samples 100-request trials).
    pub n_requests: usize,
    pub seed: u64,
}

impl<'w> ConfigEvaluator<'w> {
    /// Goodput (req/s at ≥ threshold attainment) for a configuration.
    pub fn goodput(&self, point: &ConfigPoint) -> f64 {
        let cfg = SimConfig::new(self.spec.clone(), self.device, point.to_epd());
        let result = find_goodput(
            |rate| {
                let mut rng = Rng::new(self.seed);
                let reqs = self.workload.generate(&self.spec, self.n_requests, rate, &mut rng);
                let out = Simulator::run(&cfg, &reqs);
                out.slo_attainment(self.objective.slo)
            },
            0.05,
            self.objective.threshold,
            0.05,
        );
        result.goodput
    }

    /// Eq. 1's `β·cost(p)` penalty — shared by the sequential and
    /// parallel evaluators so they can never diverge on the cost model.
    fn cost_penalty(&self, point: &ConfigPoint) -> f64 {
        let cost = self.objective.gpu_cost * point.topology.total() as f64;
        self.objective.beta * cost
    }

    /// Full objective value (Eq. 1).
    pub fn objective_value(&self, point: &ConfigPoint) -> f64 {
        self.goodput(point) - self.cost_penalty(point)
    }

    /// Evaluate goodput for a whole candidate set in parallel across
    /// `threads` scoped workers (each simulation is independent and
    /// deterministic per seed), preserving input order. `threads <= 1`
    /// degenerates to the sequential sweep; results are bit-identical at
    /// every thread count — the allocation sweep scales with cores
    /// without perturbing a single decision.
    pub fn goodput_many(&self, points: &[ConfigPoint], threads: usize) -> Vec<f64> {
        let threads = threads.max(1).min(points.len().max(1));
        if threads <= 1 {
            return points.iter().map(|p| self.goodput(p)).collect();
        }
        let chunk = points.len().div_ceil(threads);
        let mut results: Vec<Vec<f64>> = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = points
                .chunks(chunk)
                .map(|ch| s.spawn(move || ch.iter().map(|p| self.goodput(p)).collect::<Vec<f64>>()))
                .collect();
            results = handles
                .into_iter()
                .map(|h| h.join().expect("sweep worker panicked"))
                .collect();
        });
        results.into_iter().flatten().collect()
    }

    /// Parallel variant of [`ConfigEvaluator::objective_value`] over a
    /// candidate set (same ordering/determinism guarantees as
    /// [`ConfigEvaluator::goodput_many`]).
    pub fn objective_many(&self, points: &[ConfigPoint], threads: usize) -> Vec<f64> {
        self.goodput_many(points, threads)
            .into_iter()
            .zip(points)
            .map(|(f, p)| f - self.cost_penalty(p))
            .collect()
    }

    /// Mean TTFT/TPOT at a fixed rate (for the Table 5 comparison, which
    /// holds the rate at the optimized system's goodput).
    pub fn latency_at_rate(&self, point: &ConfigPoint, rate: f64) -> (f64, f64) {
        let cfg = SimConfig::new(self.spec.clone(), self.device, point.to_epd());
        let mut rng = Rng::new(self.seed);
        let reqs = self.workload.generate(&self.spec, self.n_requests, rate, &mut rng);
        let out = Simulator::run(&cfg, &reqs);
        (out.mean_ttft(), out.mean_tpot())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::{AssignPolicy, QueuePolicy};
    use crate::core::topology::Topology;
    use crate::model::spec::ModelId;
    use crate::workload::synthetic::SyntheticWorkload;

    fn evaluator(w: &SyntheticWorkload) -> ConfigEvaluator<'_> {
        ConfigEvaluator {
            spec: LmmSpec::get(ModelId::MiniCpmV26),
            device: DeviceSpec::a100(),
            workload: w,
            objective: Objective {
                beta: 0.0,
                gpu_cost: 1.0,
                slo: Slo::new(3.9, 0.06),
                threshold: 0.9,
            },
            n_requests: 30,
            seed: 42,
        }
    }

    fn point(t: Topology) -> ConfigPoint {
        ConfigPoint {
            topology: t,
            batch_e: 2,
            batch_p: 1,
            batch_d: 128,
            queue: QueuePolicy::Fcfs,
            assign: AssignPolicy::LeastLoaded,
            irp: true,
        }
    }

    #[test]
    fn sensible_config_has_positive_goodput() {
        let w = SyntheticWorkload::new(6, 10);
        let ev = evaluator(&w);
        let g = ev.goodput(&point(Topology::new(5, 2, 1)));
        assert!(g > 0.1, "goodput {g}");
    }

    #[test]
    fn starved_prefill_loses_to_balanced() {
        let w = SyntheticWorkload::new(6, 10);
        let ev = evaluator(&w);
        let balanced = ev.goodput(&point(Topology::new(5, 2, 1)));
        let starved = ev.goodput(&point(Topology::new(1, 1, 6)));
        assert!(
            balanced > starved,
            "balanced {balanced} vs encode-starved {starved}"
        );
    }

    #[test]
    fn parallel_sweep_is_thread_count_invariant() {
        // The golden-determinism requirement for the allocation sweep:
        // bit-identical goodputs at every thread count, in input order.
        let w = SyntheticWorkload::new(2, 8);
        let mut ev = evaluator(&w);
        ev.n_requests = 15;
        let points = vec![
            point(Topology::new(5, 2, 1)),
            point(Topology::new(4, 3, 1)),
            point(Topology::new(2, 2, 4)),
        ];
        let seq = ev.goodput_many(&points, 1);
        let par = ev.goodput_many(&points, 4);
        assert_eq!(seq.len(), 3);
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "thread count changed a result");
        }
        // And the sweep matches one-at-a-time evaluation exactly.
        for (p, v) in points.iter().zip(seq.iter()) {
            assert_eq!(ev.goodput(p).to_bits(), v.to_bits());
        }
        let obj = ev.objective_many(&points, 2);
        for (p, v) in points.iter().zip(obj.iter()) {
            assert_eq!(ev.objective_value(p).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn beta_penalizes_gpus() {
        let w = SyntheticWorkload::new(2, 10);
        let mut ev = evaluator(&w);
        ev.objective.beta = 100.0;
        let v = ev.objective_value(&point(Topology::new(5, 2, 1)));
        assert!(v < 0.0, "β dominates: {v}");
    }
}
