//! Online workload profiler: the shared statistics substrate behind the
//! reallocation planner (§3.2.3 + §3.2.4 unified).
//!
//! One profiler instance lives next to each control loop — the simulator
//! feeds it from simulated completions at every monitor tick, the real
//! engine's monitor thread feeds it from the worker-side counters in
//! `metrics/recorder.rs` — and both hand the same snapshot type
//! ([`WorkloadProfile`]) to the [`ReallocationPlanner`]. It maintains:
//!
//! - the per-stage queueing EWMAs the legacy controller consumed (the
//!   embedded [`QueueMonitor`], exposed unchanged so the greedy policy
//!   stays bit-for-bit),
//! - arrival-rate and request-shape EWMAs (images per request, prompt /
//!   output token means, MM tokens), and
//! - per-stage service-time EWMAs (seconds of stage work per job).
//!
//! [`ReallocationPlanner`]: super::planner::ReallocationPlanner

use crate::core::stage::Stage;

use super::monitor::QueueMonitor;

/// A point-in-time snapshot of the profiled workload, consumed by the
/// planner's topology scoring. All per-stage arrays are indexed by
/// [`Stage::index`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadProfile {
    /// Smoothed arrival rate, requests/second (0 until two arrivals).
    pub arrival_rate: f64,
    /// EWMA images per request.
    pub images_per_request: f64,
    /// EWMA prompt tokens per request.
    pub prompt_tokens: f64,
    /// EWMA requested output tokens per request.
    pub output_tokens: f64,
    /// EWMA MM tokens per request.
    pub mm_tokens: f64,
    /// EWMA seconds of stage work per job (NaN-free; 0 until observed).
    pub service: [f64; 3],
    /// EWMA queue length per stage (from the embedded monitor).
    pub queue_len: [f64; 3],
    /// EWMA backlog seconds per stage (from the embedded monitor).
    pub backlog: [f64; 3],
    /// EWMA busy fraction per stage (from the embedded monitor).
    pub utilization: [f64; 3],
    /// Live instance count per stage at the last observation.
    pub instances: [u32; 3],
}

/// The online profiler. `alpha` ∈ (0, 1] is the weight of the newest
/// observation for every EWMA it maintains (the embedded queue monitor
/// uses the same weight, so the greedy policy sees exactly the signal the
/// legacy controller saw).
#[derive(Debug, Clone)]
pub struct WorkloadProfiler {
    alpha: f64,
    monitor: QueueMonitor,
    last_arrival: Option<f64>,
    /// EWMA inter-arrival gap, seconds (0 = unknown).
    interarrival: f64,
    arrivals: u64,
    images: f64,
    prompt_tokens: f64,
    output_tokens: f64,
    mm_tokens: f64,
    shape_obs: u64,
    service: [f64; 3],
    service_obs: [u64; 3],
}

impl WorkloadProfiler {
    pub fn new(alpha: f64) -> WorkloadProfiler {
        assert!(alpha > 0.0 && alpha <= 1.0);
        WorkloadProfiler {
            alpha,
            monitor: QueueMonitor::new(alpha),
            last_arrival: None,
            interarrival: 0.0,
            arrivals: 0,
            images: 0.0,
            prompt_tokens: 0.0,
            output_tokens: 0.0,
            mm_tokens: 0.0,
            shape_obs: 0,
            service: [0.0; 3],
            service_obs: [0; 3],
        }
    }

    /// The embedded per-stage queueing monitor — handed verbatim to the
    /// greedy controller so its decisions stay bit-for-bit.
    pub fn monitor(&self) -> &QueueMonitor {
        &self.monitor
    }

    /// Feed one per-stage queueing observation (delegates to the embedded
    /// monitor with identical semantics to the pre-planner code).
    pub fn observe_stage(
        &mut self,
        stage: Stage,
        queue_len: usize,
        backlog: f64,
        utilization: f64,
        instances: u32,
    ) {
        self.monitor.observe(stage, queue_len, backlog, utilization, instances);
    }

    /// Record `n` arrivals whose latest landed at `now` (the simulator
    /// calls this per request; the engine's monitor thread calls it with
    /// the submitted-count delta of each sample window).
    pub fn note_arrivals(&mut self, n: u64, now: f64) {
        if n == 0 {
            return;
        }
        if let Some(last) = self.last_arrival {
            let gap = ((now - last) / n as f64).max(0.0);
            self.interarrival = if self.interarrival == 0.0 {
                gap // first measured gap seeds the EWMA
            } else {
                (1.0 - self.alpha) * self.interarrival + self.alpha * gap
            };
        }
        self.last_arrival = Some(now);
        self.arrivals += n;
    }

    /// Feed the shape of one request (or a window's per-request means).
    pub fn observe_request(
        &mut self,
        images: f64,
        prompt_tokens: f64,
        output_tokens: f64,
        mm_tokens: f64,
    ) {
        let a = if self.shape_obs == 0 { 1.0 } else { self.alpha };
        self.images = (1.0 - a) * self.images + a * images;
        self.prompt_tokens = (1.0 - a) * self.prompt_tokens + a * prompt_tokens;
        self.output_tokens = (1.0 - a) * self.output_tokens + a * output_tokens;
        self.mm_tokens = (1.0 - a) * self.mm_tokens + a * mm_tokens;
        self.shape_obs += 1;
    }

    /// Feed one stage-service observation: `seconds` of stage work per
    /// job (the simulator prices jobs with its cost model; the engine
    /// measures worker wall time).
    pub fn observe_service(&mut self, stage: Stage, seconds: f64) {
        let i = stage.index();
        let a = if self.service_obs[i] == 0 { 1.0 } else { self.alpha };
        self.service[i] = (1.0 - a) * self.service[i] + a * seconds.max(0.0);
        self.service_obs[i] += 1;
    }

    /// Smoothed seconds of stage work per job, if any observation landed.
    pub fn service_estimate(&self, stage: Stage) -> Option<f64> {
        if self.service_obs[stage.index()] == 0 {
            None
        } else {
            Some(self.service[stage.index()])
        }
    }

    /// Smoothed arrival rate, requests/second (0 until two arrivals).
    pub fn arrival_rate(&self) -> f64 {
        if self.interarrival <= 0.0 {
            0.0
        } else {
            1.0 / self.interarrival
        }
    }

    pub fn arrivals(&self) -> u64 {
        self.arrivals
    }

    /// Snapshot for the planner.
    pub fn profile(&self) -> WorkloadProfile {
        let mut queue_len = [0.0; 3];
        let mut backlog = [0.0; 3];
        let mut utilization = [0.0; 3];
        let mut instances = [0u32; 3];
        for s in Stage::ALL {
            let l = self.monitor.load(s);
            let i = s.index();
            queue_len[i] = l.queue_len;
            backlog[i] = l.backlog;
            utilization[i] = l.utilization;
            instances[i] = l.instances;
        }
        WorkloadProfile {
            arrival_rate: self.arrival_rate(),
            images_per_request: self.images,
            prompt_tokens: self.prompt_tokens,
            output_tokens: self.output_tokens,
            mm_tokens: self.mm_tokens,
            service: self.service,
            queue_len,
            backlog,
            utilization,
            instances,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_rate_converges() {
        let mut p = WorkloadProfiler::new(0.5);
        for k in 0..50 {
            p.note_arrivals(1, k as f64 * 0.25);
        }
        assert!((p.arrival_rate() - 4.0).abs() < 0.1, "rate {}", p.arrival_rate());
        assert_eq!(p.arrivals(), 50);
    }

    #[test]
    fn bulk_arrivals_split_the_window() {
        let mut p = WorkloadProfiler::new(1.0);
        p.note_arrivals(1, 0.0);
        p.note_arrivals(4, 1.0); // 4 arrivals over 1 s → 0.25 s gaps
        assert!((p.arrival_rate() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn shape_and_service_ewmas() {
        let mut p = WorkloadProfiler::new(0.5);
        assert!(p.service_estimate(Stage::Decode).is_none());
        p.observe_request(4.0, 22.0, 10.0, 2560.0);
        p.observe_service(Stage::Decode, 0.4);
        p.observe_service(Stage::Decode, 0.4);
        let prof = p.profile();
        assert_eq!(prof.images_per_request, 4.0, "first observation seeds the mean");
        assert!((p.service_estimate(Stage::Decode).unwrap() - 0.4).abs() < 1e-12);
        assert_eq!(p.service_estimate(Stage::Encode), None);
        assert_eq!(prof.service[Stage::Encode.index()], 0.0);
    }

    #[test]
    fn stage_observations_reach_the_monitor_unchanged() {
        // The greedy-equivalence guarantee hinges on the profiler being a
        // pure pass-through to the monitor.
        let mut p = WorkloadProfiler::new(0.3);
        let mut m = QueueMonitor::new(0.3);
        for k in 0..10 {
            let backlog = k as f64;
            p.observe_stage(Stage::Prefill, k, backlog, 0.5, 2);
            m.observe(Stage::Prefill, k, backlog, 0.5, 2);
        }
        assert_eq!(p.monitor().load(Stage::Prefill), m.load(Stage::Prefill));
        let prof = p.profile();
        assert_eq!(prof.backlog[1], m.load(Stage::Prefill).backlog);
        assert_eq!(prof.instances[1], 2);
    }
}
