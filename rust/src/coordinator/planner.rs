//! The online reallocation planner: §3.2.3's allocation optimizer and
//! §3.2.4's role switching unified into one control loop.
//!
//! The planner periodically scores candidate topologies — the
//! [`topology_neighborhood`] of the current instance counts, the same
//! move structure the offline optimizer's `ConfigPoint` space explores —
//! against the live [`WorkloadProfile`], and emits a multi-step
//! [`SwitchPlan`]: an ordered list of single-instance moves whose every
//! intermediate state respects the `min_instances` floor and never
//! strands queued work on an instance-less stage. A shared executor state
//! machine (the `pending` queue plus the per-tick release gate in
//! [`ReallocationPlanner::tick`]) drives both the simulator's
//! `begin_switch` and the real engine's `Ctrl::Switch` path, so the two
//! engines no longer fork the monitor glue.
//!
//! The legacy [`RoleSwitchController`] survives as the planner's
//! single-step fallback policy ([`PlannerPolicy::Greedy`], the default):
//! its decisions pass through the same executor, one per tick, and are
//! bit-for-bit identical to the pre-planner behavior (property-tested in
//! `rust/tests/property_planner.rs`).

use std::collections::VecDeque;

use crate::core::config::{EpdConfig, PlannerPolicy};
use crate::core::stage::Stage;
use crate::core::topology::Topology;
use crate::optimizer::space::topology_neighborhood;
use crate::optimizer::surrogate::{planner_features, SurrogateModel};
use crate::optimizer::whatif::WhatIfEvaluator;

use super::profiler::{WorkloadProfile, WorkloadProfiler};
use super::role_switch::{RoleSwitchController, SwitchDecision, SwitchPolicy};

/// An ordered multi-step reallocation: executed front to back, one step
/// per monitor tick, each step re-gated against live instance counts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SwitchPlan {
    pub steps: Vec<SwitchDecision>,
}

impl SwitchPlan {
    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }
}

/// Tunables for the planner (wraps the legacy greedy policy — its
/// `min_instances` floor and migration times are shared by both
/// policies).
#[derive(Debug, Clone, Copy)]
pub struct PlannerConfig {
    pub policy: PlannerPolicy,
    /// Seconds between planning passes (0 = every tick, legacy cadence).
    pub plan_interval: f64,
    /// The greedy controller's tunables; `min_instances` and the two
    /// migration times also govern predictive plans.
    pub switch: SwitchPolicy,
    /// Horizon (seconds) over which predicted backlog growth of an
    /// overloaded stage is charged in the topology score.
    pub horizon: f64,
    /// Neighborhood radius: candidate topologies within this many
    /// single-instance moves of the current one.
    pub radius: u32,
    /// [`PlannerPolicy::Surrogate`] only: honest what-if evaluations per
    /// planning pass (the GP forwards its EI-ranked top-k).
    pub surrogate_topk: usize,
    /// [`PlannerPolicy::Surrogate`] only: posterior-variance floor above
    /// which a candidate is forced into the honest set (exploration).
    pub surrogate_min_var: f64,
}

impl PlannerConfig {
    pub fn new(policy: PlannerPolicy, plan_interval: f64, switch: SwitchPolicy) -> PlannerConfig {
        PlannerConfig {
            policy,
            plan_interval,
            switch,
            horizon: 10.0,
            radius: 2,
            surrogate_topk: 3,
            surrogate_min_var: 0.25,
        }
    }

    /// The planner configuration an [`EpdConfig`] implies (shared by the
    /// simulator and the real engine).
    pub fn from_epd(epd: &EpdConfig, switch: SwitchPolicy) -> PlannerConfig {
        let mut cfg = PlannerConfig::new(epd.planner, epd.plan_interval, switch);
        cfg.surrogate_topk = epd.surrogate_topk.max(1);
        cfg.surrogate_min_var = epd.surrogate_min_var.max(0.0);
        cfg
    }
}

/// Plan/step counters, exported as `SimOutcome::reallocation` and via the
/// real engine's `/metrics`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReallocationStats {
    /// Plans adopted (greedy decisions count as single-step plans).
    pub plans: u64,
    /// Steps across all adopted plans.
    pub planned_steps: u64,
    /// Steps released to the executing engine.
    pub released_steps: u64,
    /// Release attempts deferred by the safety gate.
    pub blocked_steps: u64,
    /// Pending plans dropped because the cluster drifted away from their
    /// preconditions.
    pub aborted_plans: u64,
    /// Candidates scored through the GP surrogate (tier 1). Zero for
    /// greedy/predictive runs — the dormancy witness.
    pub surrogate_scored: u64,
    /// Honest short-horizon what-if simulations run (tier 2).
    pub whatif_evals: u64,
    /// Honest evaluations forced by the uncertainty floor rather than EI
    /// rank — the model re-anchoring after profile drift.
    pub forced_explorations: u64,
}

/// The surrogate policy's two evaluation tiers, boxed as one unit so the
/// dormant (greedy/predictive) planner stays a small struct.
#[derive(Debug, Clone)]
struct SurrogateEngine {
    model: SurrogateModel,
    whatif: WhatIfEvaluator,
}

/// The planner + shared plan-executor state machine.
#[derive(Debug, Clone)]
pub struct ReallocationPlanner {
    cfg: PlannerConfig,
    greedy: RoleSwitchController,
    pending: VecDeque<SwitchDecision>,
    blocked_streak: u32,
    last_plan: f64,
    stats: ReallocationStats,
    /// Present only when the owner wired a what-if evaluator for the
    /// [`PlannerPolicy::Surrogate`] policy; `None` otherwise (including
    /// surrogate runs on hosts with no simulator access, which fall back
    /// to the analytic predictive pass).
    surrogate: Option<Box<SurrogateEngine>>,
}

/// Ticks a pending step may stay gate-blocked before the whole plan is
/// declared stale and dropped (≈ 10 s at the simulator's 0.25 s tick).
const MAX_BLOCKED_TICKS: u32 = 40;

impl ReallocationPlanner {
    pub fn new(cfg: PlannerConfig) -> ReallocationPlanner {
        ReallocationPlanner {
            cfg,
            greedy: RoleSwitchController::new(cfg.switch),
            pending: VecDeque::new(),
            blocked_streak: 0,
            last_plan: f64::NEG_INFINITY,
            stats: ReallocationStats::default(),
            surrogate: None,
        }
    }

    /// Wire the honest evaluation tier for [`PlannerPolicy::Surrogate`]:
    /// a fresh GP surrogate plus the caller's what-if evaluator. Without
    /// this call a surrogate-policy planner falls back to the analytic
    /// predictive pass.
    pub fn attach_surrogate(&mut self, whatif: WhatIfEvaluator) {
        self.surrogate =
            Some(Box::new(SurrogateEngine { model: SurrogateModel::new(2.0), whatif }));
    }

    pub fn stats(&self) -> ReallocationStats {
        self.stats
    }

    /// Steps still awaiting release.
    pub fn pending_steps(&self) -> usize {
        self.pending.len()
    }

    /// Fault-aware emergency replanning (`health_replan = true`): arm the
    /// next [`ReallocationPlanner::tick`] to plan immediately instead of
    /// waiting out the remainder of `plan_interval`. A crash changes the
    /// effective topology *now*; the caller pairs this with an immediate
    /// out-of-band tick. Idempotent, and a no-op for a plan already in
    /// flight (`tick` never abandons pending steps mid-plan).
    pub fn force_plan(&mut self) {
        self.last_plan = f64::NEG_INFINITY;
    }

    /// One control tick: maybe adopt a fresh plan, then release at most
    /// one step for the caller to execute (sim `begin_switch` / engine
    /// `Ctrl::Switch`). `counts` are live non-migrating instance counts
    /// per stage; `queued[i]` flags stages with waiting work.
    pub fn tick(
        &mut self,
        now: f64,
        profiler: &WorkloadProfiler,
        counts: [u32; 3],
        queued: [bool; 3],
    ) -> Option<SwitchDecision> {
        if self.pending.is_empty() && now - self.last_plan >= self.cfg.plan_interval {
            self.last_plan = now;
            let plan = match self.cfg.policy {
                PlannerPolicy::Greedy => self
                    .greedy
                    .evaluate(now, profiler.monitor(), counts)
                    .map(|d| SwitchPlan { steps: vec![d] }),
                PlannerPolicy::Predictive => {
                    Self::plan_predictive(&self.cfg, &profiler.profile(), counts)
                }
                PlannerPolicy::Surrogate => self.plan_surrogate(&profiler.profile(), counts),
            };
            if let Some(p) = plan {
                self.stats.plans += 1;
                self.stats.planned_steps += p.steps.len() as u64;
                self.pending = p.steps.into();
            }
        }
        self.release(counts, queued)
    }

    /// The executor's per-tick release gate: the front step executes only
    /// if the donor stage can spare an instance *right now* — above the
    /// `min_instances` floor, and (for predictive plans) never leaving
    /// queued work on a stage with zero instances. Greedy steps are gated
    /// by exactly the floor check the controller itself already made with
    /// these same counts — a provable no-op, so the legacy policy stays
    /// bit-for-bit even at `min_instances = 0`. A persistently blocked
    /// plan is stale (the cluster drifted from its precondition) and is
    /// dropped whole.
    fn release(&mut self, counts: [u32; 3], queued: [bool; 3]) -> Option<SwitchDecision> {
        let step = *self.pending.front()?;
        let fi = step.from.index();
        let above_floor = counts[fi] > self.cfg.switch.min_instances;
        let safe = match self.cfg.policy {
            PlannerPolicy::Greedy => above_floor,
            PlannerPolicy::Predictive | PlannerPolicy::Surrogate => {
                above_floor && !(queued[fi] && counts[fi] <= 1)
            }
        };
        if safe {
            self.pending.pop_front();
            self.blocked_streak = 0;
            self.stats.released_steps += 1;
            return Some(step);
        }
        self.stats.blocked_steps += 1;
        self.blocked_streak += 1;
        if self.blocked_streak > MAX_BLOCKED_TICKS {
            self.pending.clear();
            self.blocked_streak = 0;
            self.stats.aborted_plans += 1;
        }
        None
    }

    /// The caller could not apply a released step (no eligible donor
    /// instance at this instant — e.g. every candidate holds an active
    /// decode batch): hand it back so the plan retries next tick instead
    /// of silently advancing past an unperformed move. Counts as a
    /// blocked release, so a permanently unplaceable plan still goes
    /// stale and is dropped. Greedy steps are *not* requeued — the legacy
    /// controller dropped unplaceable decisions (their cooldown already
    /// spent), and the bit-for-bit guarantee preserves that.
    pub fn requeue(&mut self, step: SwitchDecision) {
        if self.cfg.policy == PlannerPolicy::Greedy {
            return;
        }
        self.stats.released_steps -= 1;
        self.stats.blocked_steps += 1;
        self.blocked_streak += 1;
        self.pending.push_front(step);
        if self.blocked_streak > MAX_BLOCKED_TICKS {
            self.pending.clear();
            self.blocked_streak = 0;
            self.stats.aborted_plans += 1;
        }
    }

    /// Pure planning pass (no adoption state): score the topology
    /// neighborhood against the profile and return the best plan when it
    /// beats the current topology by more than the migration downtime it
    /// spends. Public so plan safety can be property-tested directly.
    pub fn plan_predictive(
        cfg: &PlannerConfig,
        profile: &WorkloadProfile,
        counts: [u32; 3],
    ) -> Option<SwitchPlan> {
        let cur = Topology::new(counts[0], counts[1], counts[2]);
        let floor = cfg.switch.min_instances;
        let cur_score = score_topology(profile, counts, cur, cfg.horizon);
        let mut best = cur;
        let mut best_score = cur_score;
        for cand in topology_neighborhood(cur, cfg.radius, floor) {
            let s = score_topology(profile, counts, cand, cfg.horizon);
            if s < best_score {
                best_score = s;
                best = cand;
            }
        }
        if best == cur {
            return None;
        }
        let plan = diff_to_steps(cur, best, profile, &cfg.switch);
        // Adoption hysteresis: the predicted pressure relief must outweigh
        // the migration downtime the plan spends (plus a fixed margin that
        // suppresses churn on near-ties).
        let cost: f64 = plan.steps.iter().map(|s| s.migration_time).sum();
        if cur_score - best_score <= cost + 0.25 {
            return None;
        }
        Some(plan)
    }

    /// The surrogate planning pass (two-tier evaluation): the GP scores
    /// the whole neighborhood (tier 1, microseconds per candidate) and
    /// forwards only the EI-ranked top-k — plus any candidate past the
    /// uncertainty floor, plus the analytic heuristic's pick as a safety
    /// net — to honest short-horizon what-if simulation (tier 2). Every
    /// honest score is fed back into the GP, so the model sharpens as the
    /// planner runs. Public (like [`Self::plan_predictive`]) so plan
    /// quality can be property-tested directly.
    pub fn plan_surrogate(
        &mut self,
        profile: &WorkloadProfile,
        counts: [u32; 3],
    ) -> Option<SwitchPlan> {
        // Take the engine out of `self` for the duration of the pass so
        // stats on `self` stay mutable alongside it.
        let Some(mut eng) = self.surrogate.take() else {
            // No what-if evaluator wired (e.g. the real engine's monitor
            // thread): degrade gracefully to the analytic pass.
            return Self::plan_predictive(&self.cfg, profile, counts);
        };
        let plan = self.plan_surrogate_with(&mut eng, profile, counts);
        self.surrogate = Some(eng);
        plan
    }

    fn plan_surrogate_with(
        &mut self,
        eng: &mut SurrogateEngine,
        profile: &WorkloadProfile,
        counts: [u32; 3],
    ) -> Option<SwitchPlan> {
        let cur = Topology::new(counts[0], counts[1], counts[2]);
        let floor = self.cfg.switch.min_instances;
        // Candidates that would starve a stage with work score infinite
        // analytically; drop them before they reach either tier.
        let cands: Vec<Topology> = topology_neighborhood(cur, self.cfg.radius, floor)
            .into_iter()
            .filter(|&c| score_topology(profile, counts, c, self.cfg.horizon).is_finite())
            .collect();
        if cands.is_empty() {
            return None;
        }

        // Tier 1: GP-score the whole pool.
        let feats: Vec<Vec<f64>> = cands.iter().map(|&c| planner_features(profile, c)).collect();
        self.stats.surrogate_scored += cands.len() as u64;
        let sel = eng.model.select(&feats, self.cfg.surrogate_topk, self.cfg.surrogate_min_var);
        self.stats.forced_explorations += sel.forced;

        // Honest set: the GP's picks plus the analytic heuristic's pick,
        // so the prefilter can never do worse than `plan_predictive`'s
        // choice — at worst it spends one extra honest evaluation on it.
        let mut honest = sel.chosen;
        let analytic = (0..cands.len()).min_by(|&a, &b| {
            score_topology(profile, counts, cands[a], self.cfg.horizon)
                .partial_cmp(&score_topology(profile, counts, cands[b], self.cfg.horizon))
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        if let Some(a) = analytic {
            if !honest.contains(&a) {
                honest.push(a);
            }
        }

        // Tier 2: honest what-if evaluation of the survivors (common
        // random numbers — every candidate replays the same synthetic
        // workload). Scores are negated into the GP: lower latency is a
        // higher objective.
        let cur_score = eng.whatif.score(profile, cur);
        self.stats.whatif_evals += 1;
        eng.model.observe(planner_features(profile, cur), -cur_score);
        let mut best = cur;
        let mut best_score = cur_score;
        for i in honest {
            let cand = cands[i];
            let s = eng.whatif.score(profile, cand);
            self.stats.whatif_evals += 1;
            eng.model.observe(planner_features(profile, cand), -s);
            if s < best_score {
                best_score = s;
                best = cand;
            }
        }
        if best == cur {
            return None;
        }
        let plan = diff_to_steps(cur, best, profile, &self.cfg.switch);
        // Hysteresis on the same scale as `plan_predictive`: what-if
        // scores are per-request seconds, so the relief is weighted by
        // the requests expected over one what-if horizon before being
        // compared against the migration downtime the plan spends.
        let cost: f64 = plan.steps.iter().map(|s| s.migration_time).sum();
        let weight = (profile.arrival_rate * eng.whatif.horizon).max(1.0);
        if (cur_score - best_score) * weight <= cost + 0.25 {
            return None;
        }
        Some(plan)
    }
}

/// Analytic pressure estimate of running the profiled workload on
/// candidate counts: per stage, the time to drain the current backlog at
/// the candidate's capacity, plus predicted backlog growth over `horizon`
/// when the rescaled busy-rate exceeds capacity. The busy-rate is
/// measured against the *current* instance counts and rescaled — moving
/// instances toward a stage divides its utilization and drain, exactly
/// the analytic per-stage throughput/backlog estimate the offline
/// optimizer's simulator measures the slow way. A candidate that leaves a
/// stage with work and zero instances scores infinite.
pub fn score_topology(
    profile: &WorkloadProfile,
    counts: [u32; 3],
    cand: Topology,
    horizon: f64,
) -> f64 {
    let mut worst = 0.0f64;
    for s in Stage::ALL {
        let i = s.index();
        let n = cand.count(s) as f64;
        let has_work = profile.backlog[i] > 1e-9
            || profile.queue_len[i] > 1e-9
            || profile.utilization[i] > 1e-9;
        if n == 0.0 {
            if has_work {
                return f64::INFINITY;
            }
            continue;
        }
        let rho = profile.utilization[i] * counts[i] as f64 / n;
        let drain = profile.backlog[i] / n;
        let growth = (rho - 1.0).max(0.0) * horizon;
        // The small ρ term breaks ties toward headroom without ever
        // outweighing real backlog.
        worst = worst.max(drain + growth + 0.05 * rho);
    }
    worst
}

/// Order the moves from `cur` to `target`: the most-backlogged deficit
/// stage receives first, the least-backlogged surplus stage donates
/// first. Donor counts only ever descend toward their targets and
/// receiver counts only ascend, so every intermediate state stays within
/// the per-stage envelope `[min(cur, target), max(cur, target)]` — the
/// structural half of the plan-safety property.
fn diff_to_steps(
    cur: Topology,
    target: Topology,
    profile: &WorkloadProfile,
    policy: &SwitchPolicy,
) -> SwitchPlan {
    let mut c = cur;
    let mut steps = Vec::new();
    loop {
        let to = Stage::ALL
            .into_iter()
            .filter(|&s| c.count(s) < target.count(s))
            .max_by(|a, b| {
                profile.backlog[a.index()]
                    .partial_cmp(&profile.backlog[b.index()])
                    .unwrap()
            });
        let Some(to) = to else { break };
        let from = Stage::ALL
            .into_iter()
            .filter(|&s| c.count(s) > target.count(s))
            .min_by(|a, b| {
                profile.backlog[a.index()]
                    .partial_cmp(&profile.backlog[b.index()])
                    .unwrap()
            });
        let Some(from) = from else { break };
        let migration_time = policy.migration_time(from, to);
        steps.push(SwitchDecision { from, to, migration_time });
        c.set_count(from, c.count(from) - 1);
        c.set_count(to, c.count(to) + 1);
    }
    SwitchPlan { steps }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idle_profile() -> WorkloadProfile {
        WorkloadProfile {
            arrival_rate: 0.0,
            images_per_request: 0.0,
            prompt_tokens: 0.0,
            output_tokens: 0.0,
            mm_tokens: 0.0,
            service: [0.0; 3],
            queue_len: [0.0; 3],
            backlog: [0.0; 3],
            utilization: [0.0; 3],
            instances: [2, 2, 1],
        }
    }

    fn decode_pressured() -> WorkloadProfile {
        WorkloadProfile {
            utilization: [0.05, 0.2, 1.0],
            backlog: [0.0, 0.3, 30.0],
            queue_len: [0.0, 0.5, 12.0],
            ..idle_profile()
        }
    }

    fn cfg(policy: PlannerPolicy) -> PlannerConfig {
        PlannerConfig::new(policy, 0.0, SwitchPolicy::default())
    }

    #[test]
    fn idle_cluster_never_plans() {
        let c = cfg(PlannerPolicy::Predictive);
        assert_eq!(
            ReallocationPlanner::plan_predictive(&c, &idle_profile(), [2, 2, 1]),
            None
        );
    }

    #[test]
    fn decode_pressure_yields_multi_step_plan_toward_decode() {
        let c = cfg(PlannerPolicy::Predictive);
        let plan = ReallocationPlanner::plan_predictive(&c, &decode_pressured(), [2, 2, 1])
            .expect("should reallocate");
        assert!(!plan.is_empty() && plan.len() <= 2, "radius-2 plan: {plan:?}");
        for s in &plan.steps {
            assert_eq!(s.to, Stage::Decode, "all moves feed the bottleneck");
            assert_ne!(s.from, Stage::Decode);
        }
        // The idle encode stage donates before the mildly busy prefill.
        assert_eq!(plan.steps[0].from, Stage::Encode);
    }

    #[test]
    fn plans_never_violate_the_floor() {
        let c = cfg(PlannerPolicy::Predictive);
        let plan = ReallocationPlanner::plan_predictive(&c, &decode_pressured(), [2, 2, 1])
            .unwrap_or_default();
        let mut counts = [2u32, 2, 1];
        for s in &plan.steps {
            counts[s.from.index()] -= 1;
            counts[s.to.index()] += 1;
            for &n in &counts {
                assert!(n >= c.switch.min_instances);
            }
        }
    }

    #[test]
    fn executor_releases_one_step_per_tick_and_gates_on_live_counts() {
        let mut p = ReallocationPlanner::new(cfg(PlannerPolicy::Predictive));
        let prof = {
            let mut w = WorkloadProfiler::new(0.3);
            let d = decode_pressured();
            for s in Stage::ALL {
                let i = s.index();
                let counts: [u32; 3] = [2, 2, 1];
                w.observe_stage(
                    s,
                    d.queue_len[i] as usize,
                    d.backlog[i],
                    d.utilization[i],
                    counts[i],
                );
            }
            w
        };
        let queued = [false, false, true];
        let mut counts = [2u32, 2, 1];
        let s1 = p.tick(0.0, &prof, counts, queued).expect("first step");
        counts[s1.from.index()] -= 1;
        counts[s1.to.index()] += 1;
        let stats = p.stats();
        assert_eq!(stats.plans, 1);
        assert!(stats.planned_steps >= 1);
        // Remaining steps release on later ticks, never two at once.
        let mut released = 1;
        for k in 1..10 {
            if let Some(s) = p.tick(k as f64 * 0.25, &prof, counts, queued) {
                counts[s.from.index()] -= 1;
                counts[s.to.index()] += 1;
                released += 1;
            }
            for &n in &counts {
                assert!(n >= 1);
            }
        }
        assert_eq!(released as u64, p.stats().released_steps);
    }

    #[test]
    fn force_plan_overrides_the_interval_gate() {
        let mut c = cfg(PlannerPolicy::Predictive);
        c.plan_interval = 100.0;
        let mut p = ReallocationPlanner::new(c);
        let prof = {
            let mut w = WorkloadProfiler::new(0.3);
            let d = decode_pressured();
            for s in Stage::ALL {
                let i = s.index();
                let base: [u32; 3] = [2, 2, 1];
                w.observe_stage(s, d.queue_len[i] as usize, d.backlog[i], d.utilization[i], base[i]);
            }
            w
        };
        let queued = [false, false, true];
        let mut counts = [2u32, 2, 1];
        let s1 = p.tick(0.0, &prof, counts, queued).expect("initial plan");
        counts[s1.from.index()] -= 1;
        counts[s1.to.index()] += 1;
        for k in 1..10 {
            if let Some(s) = p.tick(k as f64, &prof, counts, queued) {
                counts[s.from.index()] -= 1;
                counts[s.to.index()] += 1;
            }
        }
        assert_eq!(p.stats().plans, 1);
        // Same pressure again well inside the interval: the gate holds...
        assert!(p.tick(50.0, &prof, [2, 2, 1], queued).is_none(), "interval gate");
        assert_eq!(p.stats().plans, 1);
        // ...until a crash forces an out-of-band emergency pass.
        p.force_plan();
        assert!(p.tick(50.5, &prof, [2, 2, 1], queued).is_some(), "emergency replan");
        assert_eq!(p.stats().plans, 2);
    }

    #[test]
    fn blocked_plan_is_eventually_dropped() {
        let mut p = ReallocationPlanner::new(cfg(PlannerPolicy::Predictive));
        p.pending.push_back(SwitchDecision {
            from: Stage::Encode,
            to: Stage::Decode,
            migration_time: 0.7,
        });
        // Donor already at the floor: the gate must hold the step, then
        // drop the stale plan.
        for k in 0..=MAX_BLOCKED_TICKS {
            assert_eq!(p.release([1, 2, 1], [false; 3]), None, "tick {k}");
        }
        assert_eq!(p.pending_steps(), 0);
        assert_eq!(p.stats().aborted_plans, 1);
        assert!(p.stats().blocked_steps > 0);
    }

    #[test]
    fn unplaceable_predictive_step_is_requeued_and_greedy_is_dropped() {
        let mut p = ReallocationPlanner::new(cfg(PlannerPolicy::Predictive));
        let step = SwitchDecision { from: Stage::Encode, to: Stage::Decode, migration_time: 0.7 };
        p.pending.push_back(step);
        let released = p.release([2, 2, 1], [false; 3]).expect("gate passes");
        assert_eq!(p.stats().released_steps, 1);
        p.requeue(released);
        assert_eq!(p.stats().released_steps, 0, "release undone");
        assert_eq!(p.pending_steps(), 1, "step back at the front");
        // Greedy keeps the legacy drop semantics (cooldown already spent).
        let mut g = ReallocationPlanner::new(cfg(PlannerPolicy::Greedy));
        g.requeue(step);
        assert_eq!(g.pending_steps(), 0);
        assert_eq!(g.stats(), ReallocationStats::default());
    }

    #[test]
    fn zero_instance_stage_with_queued_work_is_never_created() {
        // min_instances = 0 allows draining a stage — but not one that
        // still has queued work.
        let pol = SwitchPolicy { min_instances: 0, ..SwitchPolicy::default() };
        let mut p =
            ReallocationPlanner::new(PlannerConfig::new(PlannerPolicy::Predictive, 0.0, pol));
        p.pending.push_back(SwitchDecision {
            from: Stage::Prefill,
            to: Stage::Decode,
            migration_time: 0.1,
        });
        assert_eq!(p.release([2, 1, 1], [false, true, false]), None, "queued work blocks");
        assert!(p.release([2, 1, 1], [false, false, false]).is_some(), "idle stage may drain");
    }

    #[test]
    fn surrogate_without_evaluator_falls_back_to_analytic_planning() {
        let mut p = ReallocationPlanner::new(cfg(PlannerPolicy::Surrogate));
        let plan = p
            .plan_surrogate(&decode_pressured(), [2, 2, 1])
            .expect("fallback must still relieve decode pressure");
        assert!(!plan.is_empty());
        for s in &plan.steps {
            assert_eq!(s.to, Stage::Decode);
        }
        // Analytic fallback touches neither tier.
        assert_eq!(p.stats().surrogate_scored, 0);
        assert_eq!(p.stats().whatif_evals, 0);
        assert_eq!(p.stats().forced_explorations, 0);
    }

    #[test]
    fn surrogate_with_evaluator_runs_both_tiers() {
        use crate::model::spec::{DeviceSpec, LmmSpec, ModelId};
        let mut p = ReallocationPlanner::new(cfg(PlannerPolicy::Surrogate));
        let epd = EpdConfig::epd(Topology::new(2, 2, 1), 1, 1, 2);
        p.attach_surrogate(WhatIfEvaluator::new(
            LmmSpec::get(ModelId::MiniCpmV26),
            DeviceSpec::a100(),
            &epd,
        ));
        let prof = WorkloadProfile {
            arrival_rate: 2.5,
            prompt_tokens: 64.0,
            output_tokens: 160.0,
            ..decode_pressured()
        };
        let plan = p.plan_surrogate(&prof, [2, 2, 1]);
        let stats = p.stats();
        assert!(stats.surrogate_scored > 0, "tier 1 must score the neighborhood");
        assert!(
            stats.whatif_evals >= 2,
            "tier 2 must honestly evaluate current + survivors: {stats:?}"
        );
        assert!(
            stats.whatif_evals < stats.surrogate_scored + 2,
            "the prefilter must evaluate fewer candidates than it scores"
        );
        if let Some(plan) = plan {
            for s in &plan.steps {
                assert_eq!(s.to, Stage::Decode, "moves feed the bottleneck: {plan:?}");
            }
        }
        // The honest evaluations trained the model.
        assert!(p.surrogate.as_ref().unwrap().model.observations() >= 2);
    }

    #[test]
    fn score_rescales_with_candidate_capacity() {
        let prof = decode_pressured();
        let counts = [2, 2, 1];
        let cur = score_topology(&prof, counts, Topology::new(2, 2, 1), 10.0);
        let shifted = score_topology(&prof, counts, Topology::new(1, 1, 3), 10.0);
        assert!(shifted < cur, "moving capacity to decode must relieve pressure");
        // A stage with work and no instances is never acceptable.
        let starved = score_topology(&prof, counts, Topology::new(2, 0, 3), 10.0);
        assert!(starved.is_infinite());
    }
}
