//! The paper's coordination layer: intra-request parallelism (§3.2.2),
//! EP/PD migration accounting (§3.2.1), dynamic role switching (§3.2.4),
//! and the online reallocation planner that unifies role switching with
//! the §3.2.3 allocation optimizer (workload profiler → topology planner
//! → shared plan executor). These are pure policy components consumed by
//! both the discrete-event simulator and the real engine.

pub mod irp;
pub mod migration;
pub mod monitor;
pub mod planner;
pub mod profiler;
pub mod role_switch;

pub use irp::{plan_shards, plan_shards_aligned, ShardPlan};
pub use migration::{MigrationKind, TransferModel};
pub use monitor::{QueueMonitor, StageLoad};
pub use planner::{PlannerConfig, ReallocationPlanner, ReallocationStats, SwitchPlan};
pub use profiler::{WorkloadProfile, WorkloadProfiler};
pub use role_switch::{RoleSwitchController, SwitchDecision, SwitchPolicy};
