//! The paper's coordination layer: intra-request parallelism (§3.2.2),
//! EP/PD migration accounting (§3.2.1), and dynamic role switching
//! (§3.2.4). These are pure policy components consumed by both the
//! discrete-event simulator and the real engine.

pub mod irp;
pub mod migration;
pub mod monitor;
pub mod role_switch;

pub use irp::{plan_shards, plan_shards_aligned, ShardPlan};
pub use migration::{MigrationKind, TransferModel};
pub use monitor::{QueueMonitor, StageLoad};
pub use role_switch::{RoleSwitchController, SwitchDecision, SwitchPolicy};
