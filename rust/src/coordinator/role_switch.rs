//! Dynamic role switching (§3.2.4): move an instance from its current
//! stage to the bottleneck stage via offload → migrate → onload.
//!
//! The controller watches the [`QueueMonitor`](super::monitor::QueueMonitor)
//! pressure signals and proposes a switch when the imbalance between the
//! most- and least-pressured stages exceeds a hysteresis threshold. The
//! migration itself costs time: the paper measures < 0.7 s when the E stage
//! is involved (model + cache type change) and much less for P↔D (LLM and
//! KV cache are reused).

use crate::core::stage::Stage;

use super::monitor::QueueMonitor;

/// Tunables for the switch policy.
#[derive(Debug, Clone, Copy)]
pub struct SwitchPolicy {
    /// Minimum ratio of max-stage to min-stage pressure before switching.
    pub imbalance_ratio: f64,
    /// Minimum absolute pressure (seconds of backlog per instance) at the
    /// bottleneck before a switch is worth the disruption.
    pub min_pressure: f64,
    /// Cool-down between switches, seconds.
    pub cooldown: f64,
    /// Never leave a stage with fewer than this many instances.
    pub min_instances: u32,
    /// Migration duration when the encode stage is source or target
    /// (model weights + cache type change). Paper: ≲ 0.7 s.
    pub switch_time_with_e: f64,
    /// Migration duration for P↔D (weights and KV cache reused).
    pub switch_time_pd: f64,
}

impl Default for SwitchPolicy {
    fn default() -> Self {
        SwitchPolicy {
            imbalance_ratio: 3.0,
            min_pressure: 1.0,
            cooldown: 5.0,
            min_instances: 1,
            switch_time_with_e: 0.7,
            switch_time_pd: 0.1,
        }
    }
}

impl SwitchPolicy {
    /// Migration time for a `from → to` switch (§3.2.4: edges touching
    /// the encode stage change model weights and cache type and cost
    /// ≲ 0.7 s; P↔D reuses both). The single pricing rule shared by the
    /// greedy controller and the predictive planner's plans.
    pub fn migration_time(&self, from: Stage, to: Stage) -> f64 {
        if from == Stage::Encode || to == Stage::Encode {
            self.switch_time_with_e
        } else {
            self.switch_time_pd
        }
    }
}

/// A proposed role switch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SwitchDecision {
    pub from: Stage,
    pub to: Stage,
    /// How long the migrating instance is offline.
    pub migration_time: f64,
}

/// The §3.2.4 controller.
#[derive(Debug, Clone)]
pub struct RoleSwitchController {
    policy: SwitchPolicy,
    last_switch: f64,
    switches: u32,
}

impl RoleSwitchController {
    pub fn new(policy: SwitchPolicy) -> RoleSwitchController {
        RoleSwitchController {
            policy,
            last_switch: f64::NEG_INFINITY,
            switches: 0,
        }
    }

    pub fn switches_made(&self) -> u32 {
        self.switches
    }

    /// Migration time for a given edge (delegates to the policy's rule).
    pub fn migration_time(&self, from: Stage, to: Stage) -> f64 {
        self.policy.migration_time(from, to)
    }

    /// Evaluate the monitor at time `now`; maybe propose a switch.
    /// `instance_counts` are the current live counts per stage (E, P, D).
    pub fn evaluate(
        &mut self,
        now: f64,
        monitor: &QueueMonitor,
        instance_counts: [u32; 3],
    ) -> Option<SwitchDecision> {
        if now - self.last_switch < self.policy.cooldown {
            return None;
        }
        let (hi, _) = monitor.extremes();
        let hi_p = monitor.load(hi).pressure();
        if hi_p < self.policy.min_pressure {
            return None;
        }
        // Donor: the least-pressured *eligible* stage — one that is not the
        // bottleneck and still has instances to spare above the floor.
        let count_of = |s: Stage| match s {
            Stage::Encode => instance_counts[0],
            Stage::Prefill => instance_counts[1],
            Stage::Decode => instance_counts[2],
        };
        let lo = Stage::ALL
            .into_iter()
            .filter(|&s| s != hi && count_of(s) > self.policy.min_instances)
            .min_by(|&a, &b| {
                monitor
                    .load(a)
                    .pressure()
                    .partial_cmp(&monitor.load(b).pressure())
                    .unwrap()
            })?;
        let lo_p = monitor.load(lo).pressure();
        // Ratio test with care for lo_p == 0 (idle donor stage).
        let imbalanced = if lo_p <= 0.0 {
            true
        } else {
            hi_p / lo_p >= self.policy.imbalance_ratio
        };
        if !imbalanced {
            return None;
        }
        self.last_switch = now;
        self.switches += 1;
        Some(SwitchDecision {
            from: lo,
            to: hi,
            migration_time: self.migration_time(lo, hi),
        })
    }

    /// The offload step (§3.2.4): requeue a draining instance's items onto
    /// its siblings (pure function; callers apply it to their queue type).
    /// Returns, for each drained item index, the sibling index it goes to
    /// (round-robin for even spread).
    pub fn offload_targets(num_items: usize, num_siblings: usize) -> Vec<usize> {
        assert!(num_siblings > 0, "offload requires at least one sibling");
        (0..num_items).map(|i| i % num_siblings).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn monitor_with(e: f64, p: f64, d: f64, counts: [u32; 3]) -> QueueMonitor {
        let mut m = QueueMonitor::new(1.0);
        m.observe(Stage::Encode, 0, e * counts[0] as f64, 0.5, counts[0]);
        m.observe(Stage::Prefill, 0, p * counts[1] as f64, 0.5, counts[1]);
        m.observe(Stage::Decode, 0, d * counts[2] as f64, 0.5, counts[2]);
        m
    }

    #[test]
    fn switches_to_bottleneck() {
        // The paper's Table 6 scenario: decode becomes the bottleneck, an
        // encode instance should move E→D.
        let mut c = RoleSwitchController::new(SwitchPolicy::default());
        let m = monitor_with(0.1, 0.5, 30.0, [5, 1, 2]);
        let d = c.evaluate(100.0, &m, [5, 1, 2]).expect("should switch");
        assert_eq!(d.from, Stage::Encode);
        assert_eq!(d.to, Stage::Decode);
        assert!((d.migration_time - 0.7).abs() < 1e-9);
    }

    #[test]
    fn pd_switch_is_cheap() {
        let c = RoleSwitchController::new(SwitchPolicy::default());
        assert!(c.migration_time(Stage::Prefill, Stage::Decode) < 0.2);
        assert!(c.migration_time(Stage::Encode, Stage::Decode) >= 0.7);
    }

    #[test]
    fn respects_cooldown() {
        let mut c = RoleSwitchController::new(SwitchPolicy::default());
        let m = monitor_with(0.1, 0.5, 30.0, [5, 1, 2]);
        assert!(c.evaluate(10.0, &m, [5, 1, 2]).is_some());
        assert!(c.evaluate(11.0, &m, [4, 1, 3]).is_none(), "cooldown");
        assert!(c.evaluate(16.0, &m, [4, 1, 3]).is_some());
    }

    #[test]
    fn never_drains_last_instance() {
        let mut c = RoleSwitchController::new(SwitchPolicy::default());
        // Decode is the bottleneck; encode and prefill are idle but both
        // sit at the 1-instance floor — the controller must refuse.
        let m = monitor_with(0.0, 0.2, 30.0, [1, 1, 2]);
        assert!(c.evaluate(10.0, &m, [1, 1, 2]).is_none());
    }

    #[test]
    fn falls_back_to_next_donor_when_least_is_at_floor() {
        // Prefill is the least pressured but has only 1 instance; encode
        // (slightly busier, 5 instances) must be chosen instead — the
        // Table 6 scenario.
        let mut c = RoleSwitchController::new(SwitchPolicy::default());
        let m = monitor_with(0.05, 0.0, 20.0, [5, 1, 2]);
        let d = c.evaluate(10.0, &m, [5, 1, 2]).expect("switch");
        assert_eq!(d.from, Stage::Encode);
        assert_eq!(d.to, Stage::Decode);
    }

    #[test]
    fn quiet_system_never_switches() {
        let mut c = RoleSwitchController::new(SwitchPolicy::default());
        let m = monitor_with(0.01, 0.02, 0.03, [2, 2, 2]);
        assert!(c.evaluate(10.0, &m, [2, 2, 2]).is_none());
    }

    #[test]
    fn offload_spreads_evenly() {
        let t = RoleSwitchController::offload_targets(7, 3);
        assert_eq!(t, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn balanced_pressure_below_ratio_no_switch() {
        let mut c = RoleSwitchController::new(SwitchPolicy::default());
        let m = monitor_with(2.0, 2.5, 3.0, [2, 2, 2]);
        assert!(c.evaluate(10.0, &m, [2, 2, 2]).is_none());
    }
}
