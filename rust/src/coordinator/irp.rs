//! Intra-Request Parallelism (§3.2.2): shard one request's tiles across
//! multiple encode instances. Tiles are encoded independently, so the
//! request's tiles are split as evenly as possible across up to
//! `max_fanout` workers; each shard is an independent encoding job whose
//! tokens are transferred asynchronously and merged at the prefill side.

/// The shard layout for one request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardPlan {
    /// Tiles assigned to each shard (non-empty, sums to total tiles).
    pub tiles_per_shard: Vec<u32>,
}

impl ShardPlan {
    pub fn num_shards(&self) -> u32 {
        self.tiles_per_shard.len() as u32
    }

    pub fn total_tiles(&self) -> u32 {
        self.tiles_per_shard.iter().sum()
    }

    /// The largest shard — encode completion time is governed by it.
    pub fn max_shard_tiles(&self) -> u32 {
        self.tiles_per_shard.iter().copied().max().unwrap_or(0)
    }
}

/// Split `total_tiles` across at most `max_fanout` encode workers. With
/// IRP disabled (or a single worker) the plan is one shard. Never creates
/// empty shards: fan-out is capped at the tile count.
pub fn plan_shards(total_tiles: u32, max_fanout: u32, irp_enabled: bool) -> ShardPlan {
    if total_tiles == 0 {
        return ShardPlan { tiles_per_shard: vec![] };
    }
    let fanout = if irp_enabled {
        max_fanout.max(1).min(total_tiles)
    } else {
        1
    };
    let base = total_tiles / fanout;
    let rem = total_tiles % fanout;
    let tiles_per_shard = (0..fanout)
        .map(|i| base + if i < rem { 1 } else { 0 })
        .collect();
    ShardPlan { tiles_per_shard }
}

/// Like [`plan_shards`], but with shard boundaries aligned to multiples of
/// `align_tiles` so IRP composes with chunked EP streaming: when encoder
/// shards emit fixed-size token chunks, alignment guarantees every chunk's
/// tiles live on one shard — no chunk straddles two encode instances.
/// Every shard except possibly the last is a whole number of alignment
/// units; the last absorbs the remainder. `align_tiles <= 1` degrades to
/// [`plan_shards`].
pub fn plan_shards_aligned(
    total_tiles: u32,
    max_fanout: u32,
    irp_enabled: bool,
    align_tiles: u32,
) -> ShardPlan {
    if align_tiles <= 1 {
        return plan_shards(total_tiles, max_fanout, irp_enabled);
    }
    if total_tiles == 0 {
        return ShardPlan { tiles_per_shard: vec![] };
    }
    if !irp_enabled || max_fanout <= 1 {
        return ShardPlan { tiles_per_shard: vec![total_tiles] };
    }
    // Distribute whole alignment units across the fan-out, then trim the
    // final shard back to the true tile count.
    let units = total_tiles.div_ceil(align_tiles);
    let fanout = max_fanout.min(units).max(1);
    let base = units / fanout;
    let rem = units % fanout;
    let mut tiles_per_shard: Vec<u32> = (0..fanout)
        .map(|i| (base + if i < rem { 1 } else { 0 }) * align_tiles)
        .collect();
    let overshoot = units * align_tiles - total_tiles;
    let last = tiles_per_shard.len() - 1;
    debug_assert!(overshoot < align_tiles && tiles_per_shard[last] > overshoot);
    tiles_per_shard[last] -= overshoot;
    ShardPlan { tiles_per_shard }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_split() {
        let p = plan_shards(12, 4, true);
        assert_eq!(p.tiles_per_shard, vec![3, 3, 3, 3]);
        assert_eq!(p.max_shard_tiles(), 3);
    }

    #[test]
    fn uneven_split_front_loaded() {
        let p = plan_shards(10, 4, true);
        assert_eq!(p.tiles_per_shard, vec![3, 3, 2, 2]);
        assert_eq!(p.total_tiles(), 10);
    }

    #[test]
    fn fanout_capped_by_tiles() {
        let p = plan_shards(3, 8, true);
        assert_eq!(p.num_shards(), 3);
        assert_eq!(p.tiles_per_shard, vec![1, 1, 1]);
    }

    #[test]
    fn disabled_is_single_shard() {
        let p = plan_shards(40, 5, false);
        assert_eq!(p.num_shards(), 1);
        assert_eq!(p.tiles_per_shard, vec![40]);
    }

    #[test]
    fn zero_tiles() {
        let p = plan_shards(0, 4, true);
        assert_eq!(p.num_shards(), 0);
        assert_eq!(p.max_shard_tiles(), 0);
    }

    /// IRP's headline effect (Table 4): max shard shrinks ~linearly with
    /// fan-out, so encode latency does too.
    #[test]
    fn speedup_scales_with_fanout() {
        let serial = plan_shards(40, 1, true).max_shard_tiles();
        let par5 = plan_shards(40, 5, true).max_shard_tiles();
        assert_eq!(serial, 40);
        assert_eq!(par5, 8);
    }

    #[test]
    fn aligned_split_keeps_chunk_boundaries() {
        // 60 tiles, fan-out 5, chunks of 8 tiles: 8 units over 5 workers,
        // tail shard trimmed by the 4-tile overshoot.
        let p = plan_shards_aligned(60, 5, true, 8);
        assert_eq!(p.tiles_per_shard, vec![16, 16, 16, 8, 4]);
        assert_eq!(p.total_tiles(), 60);
        for &t in &p.tiles_per_shard[..p.tiles_per_shard.len() - 1] {
            assert_eq!(t % 8, 0, "non-final shard off chunk boundary");
        }
    }

    #[test]
    fn aligned_degrades_to_plain_plan() {
        assert_eq!(plan_shards_aligned(40, 5, true, 1), plan_shards(40, 5, true));
        assert_eq!(plan_shards_aligned(40, 5, true, 0), plan_shards(40, 5, true));
        assert_eq!(plan_shards_aligned(40, 5, false, 8).tiles_per_shard, vec![40]);
        assert_eq!(plan_shards_aligned(0, 5, true, 8).num_shards(), 0);
    }

    #[test]
    fn aligned_caps_fanout_at_units() {
        // 10 tiles in 8-tile units = 2 units: at most 2 shards even with
        // fan-out 5, and the tail shard carries the 2-tile remainder.
        let p = plan_shards_aligned(10, 5, true, 8);
        assert_eq!(p.tiles_per_shard, vec![8, 2]);
    }

    /// Property: aligned plans partition the tiles with no empty shard and
    /// every non-final shard a whole number of alignment units.
    #[test]
    fn aligned_partition_property() {
        use crate::util::quickcheck::{forall, pair, usize_in};
        forall(
            pair(pair(usize_in(1, 500), usize_in(1, 16)), usize_in(1, 64)),
            |&((tiles, fanout), align)| {
                let p = plan_shards_aligned(tiles as u32, fanout as u32, true, align as u32);
                if p.total_tiles() != tiles as u32 {
                    return Err(format!("lost tiles: {p:?}"));
                }
                if p.num_shards() > fanout as u32 {
                    return Err(format!("fan-out exceeded: {p:?}"));
                }
                if p.tiles_per_shard.iter().any(|&t| t == 0) {
                    return Err(format!("empty shard: {p:?}"));
                }
                if align > 1 {
                    let n = p.tiles_per_shard.len();
                    for &t in &p.tiles_per_shard[..n - 1] {
                        if t % align as u32 != 0 {
                            return Err(format!("misaligned shard: {p:?}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    /// Property: shards always partition the tiles, no shard empty.
    #[test]
    fn partition_property() {
        use crate::util::quickcheck::{forall, pair, usize_in};
        forall(
            pair(usize_in(1, 500), usize_in(1, 16)),
            |&(tiles, fanout)| {
                let p = plan_shards(tiles as u32, fanout as u32, true);
                if p.total_tiles() != tiles as u32 {
                    return Err(format!("lost tiles: {:?}", p));
                }
                if p.tiles_per_shard.iter().any(|&t| t == 0) {
                    return Err(format!("empty shard: {:?}", p));
                }
                let max = p.max_shard_tiles();
                let min = p.tiles_per_shard.iter().copied().min().unwrap();
                if max - min > 1 {
                    return Err(format!("imbalanced: {:?}", p));
                }
                Ok(())
            },
        );
    }
}
