//! Inter-stage migration (§3.1, §3.2.1): the EP transfer moves multimodal
//! tokens (encode → prefill MM cache), the PD transfer moves the KV cache
//! and first token (prefill → decode). Transfers are asynchronous — the
//! source instance keeps serving while the transfer is in flight — so the
//! model here only computes *what* moves and *how long* it takes on a
//! given interconnect.

use crate::model::spec::{DeviceSpec, LmmSpec};

/// Which migration edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationKind {
    /// Encode → prefill: multimodal token embeddings.
    EncodeToPrefill,
    /// Prefill → decode: KV cache + first token.
    PrefillToDecode,
}

/// Byte-accounting + latency model for inter-instance transfers.
#[derive(Debug, Clone)]
pub struct TransferModel {
    /// Link bandwidth, bytes/s (NVLink intra-node, IB inter-node).
    pub bandwidth: f64,
    /// Per-transfer latency floor, seconds.
    pub latency: f64,
}

impl TransferModel {
    pub fn from_device(dev: &DeviceSpec) -> TransferModel {
        TransferModel {
            bandwidth: dev.link_bw,
            latency: dev.link_latency,
        }
    }

    /// Bytes moved by a migration for a request with the given token
    /// counts.
    pub fn bytes(&self, kind: MigrationKind, spec: &LmmSpec, mm_tokens: u64, kv_tokens: u64) -> u64 {
        match kind {
            // MM token embeddings at fp16: tokens × hidden × 2.
            MigrationKind::EncodeToPrefill => mm_tokens * spec.mm_token_bytes(),
            // Full KV cache of the prefilled sequence.
            MigrationKind::PrefillToDecode => kv_tokens * spec.llm.kv_bytes_per_token(),
        }
    }

    /// Transfer time, seconds.
    pub fn time(&self, bytes: u64) -> f64 {
        self.latency + bytes as f64 / self.bandwidth
    }

    /// Convenience: time for a migration given token counts.
    pub fn migration_time(
        &self,
        kind: MigrationKind,
        spec: &LmmSpec,
        mm_tokens: u64,
        kv_tokens: u64,
    ) -> f64 {
        self.time(self.bytes(kind, spec, mm_tokens, kv_tokens))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    fn setup() -> (TransferModel, LmmSpec) {
        (
            TransferModel::from_device(&DeviceSpec::a100()),
            LmmSpec::get(ModelId::InternVl2_8b),
        )
    }

    #[test]
    fn ep_bytes_are_embedding_bytes() {
        let (t, spec) = setup();
        // 3328 MM tokens (one 4K image) × 4096 hidden × 2 B ≈ 27.3 MB.
        let b = t.bytes(MigrationKind::EncodeToPrefill, &spec, 3328, 0);
        assert_eq!(b, 3328 * 4096 * 2);
    }

    #[test]
    fn pd_bytes_are_kv_bytes() {
        let (t, spec) = setup();
        let b = t.bytes(MigrationKind::PrefillToDecode, &spec, 0, 13_334);
        assert_eq!(b, 13_334 * 131_072);
    }

    #[test]
    fn pd_dominates_ep_for_long_context() {
        // The paper's asymmetry: KV moves ~64× more bytes per token than
        // MM embeddings for InternVL2-8B (131072 vs 8192 B/token).
        let (t, spec) = setup();
        let ep = t.migration_time(MigrationKind::EncodeToPrefill, &spec, 13_334, 0);
        let pd = t.migration_time(MigrationKind::PrefillToDecode, &spec, 0, 13_334);
        assert!(pd > 5.0 * ep);
    }

    #[test]
    fn latency_floor_applies() {
        let t = TransferModel { bandwidth: 300e9, latency: 1e-3 };
        assert!(t.time(0) >= 1e-3);
        // 3 GB at 300 GB/s = 10 ms + 1 ms floor.
        assert!((t.time(3_000_000_000) - 0.011).abs() < 1e-6);
    }
}
