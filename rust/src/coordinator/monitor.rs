//! Queue monitoring (§3.2.4): exponentially-smoothed per-stage queueing
//! statistics that drive the role-switch controller.

use crate::core::stage::Stage;

/// Smoothed load signal for one stage.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StageLoad {
    /// EWMA of queue length (requests).
    pub queue_len: f64,
    /// EWMA of queue backlog (estimated seconds of work).
    pub backlog: f64,
    /// EWMA of instance busy fraction.
    pub utilization: f64,
    /// Instances currently serving this stage.
    pub instances: u32,
}

impl StageLoad {
    fn zero() -> StageLoad {
        StageLoad { queue_len: 0.0, backlog: 0.0, utilization: 0.0, instances: 0 }
    }

    /// Backlog seconds per instance — the controller's pressure signal.
    pub fn pressure(&self) -> f64 {
        if self.instances == 0 {
            // A stage with work but no instances is infinitely pressured.
            if self.backlog > 0.0 || self.queue_len > 0.0 {
                return f64::INFINITY;
            }
            return 0.0;
        }
        self.backlog / self.instances as f64
    }
}

/// EWMA monitor across the three stages.
#[derive(Debug, Clone)]
pub struct QueueMonitor {
    alpha: f64,
    loads: [StageLoad; 3],
}

impl QueueMonitor {
    /// `alpha` ∈ (0, 1]: weight of the newest observation.
    pub fn new(alpha: f64) -> QueueMonitor {
        assert!(alpha > 0.0 && alpha <= 1.0);
        QueueMonitor {
            alpha,
            loads: [StageLoad::zero(); 3],
        }
    }

    /// Feed one observation for a stage.
    pub fn observe(
        &mut self,
        stage: Stage,
        queue_len: usize,
        backlog: f64,
        utilization: f64,
        instances: u32,
    ) {
        let a = self.alpha;
        let l = &mut self.loads[stage.index()];
        l.queue_len = (1.0 - a) * l.queue_len + a * queue_len as f64;
        l.backlog = (1.0 - a) * l.backlog + a * backlog;
        l.utilization = (1.0 - a) * l.utilization + a * utilization.clamp(0.0, 1.0);
        l.instances = instances;
    }

    pub fn load(&self, stage: Stage) -> StageLoad {
        self.loads[stage.index()]
    }

    /// The most and least pressured stages right now.
    pub fn extremes(&self) -> (Stage, Stage) {
        let mut hi = Stage::Encode;
        let mut lo = Stage::Encode;
        for s in Stage::ALL {
            if self.load(s).pressure() > self.load(hi).pressure() {
                hi = s;
            }
            if self.load(s).pressure() < self.load(lo).pressure() {
                lo = s;
            }
        }
        (hi, lo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges() {
        let mut m = QueueMonitor::new(0.5);
        for _ in 0..20 {
            m.observe(Stage::Decode, 10, 5.0, 1.0, 2);
        }
        let l = m.load(Stage::Decode);
        assert!((l.queue_len - 10.0).abs() < 0.1);
        assert!((l.backlog - 5.0).abs() < 0.1);
        assert!((l.pressure() - 2.5).abs() < 0.1);
    }

    #[test]
    fn extremes_identify_bottleneck() {
        let mut m = QueueMonitor::new(1.0);
        m.observe(Stage::Encode, 0, 0.1, 0.2, 5);
        m.observe(Stage::Prefill, 2, 1.0, 0.9, 1);
        m.observe(Stage::Decode, 50, 40.0, 1.0, 2);
        let (hi, lo) = m.extremes();
        assert_eq!(hi, Stage::Decode);
        assert_eq!(lo, Stage::Encode);
    }

    #[test]
    fn empty_stage_with_work_is_infinite_pressure() {
        let mut m = QueueMonitor::new(1.0);
        m.observe(Stage::Prefill, 3, 2.0, 0.0, 0);
        assert!(m.load(Stage::Prefill).pressure().is_infinite());
    }

    #[test]
    fn idle_empty_stage_zero_pressure() {
        let m = QueueMonitor::new(0.3);
        assert_eq!(m.load(Stage::Encode).pressure(), 0.0);
    }
}
