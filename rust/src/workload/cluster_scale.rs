//! Cluster-scale mixed workload: the traffic shape the ROADMAP's
//! "millions of users" north star implies — a chat-dominated stream with
//! a many-image vision minority — replayed against a 64-instance EPD
//! topology. This is the workload `benches/perf_sim_throughput.rs` gates
//! the simulator fast path on (≥1M requests, live request state bounded
//! by in-flight, events/sec vs the pre-refactor baseline) and the one
//! `simulate --workload cluster-scale --no-timelines` exposes on the CLI.
//!
//! Two request classes, mixed per-arrival by a Bernoulli draw:
//!
//! - **Chat**: text-only, longer prompt, long-ish output — decode-bound.
//! - **Vision**: several 4K images, short prompt/output — encode-bound.
//!
//! The default 64-GPU topology keeps the paper's encode-heavy 5:2:1
//! shape (40E/16P/8D); at the default mix the cluster sustains roughly
//! 60–100 req/s, so benchmark rates are chosen below saturation to keep
//! in-flight — and therefore live simulator state — bounded.

use super::{build_request, Workload};
use crate::core::config::EpdConfig;
use crate::core::request::Request;
use crate::core::topology::Topology;
use crate::model::spec::{DeviceSpec, LmmSpec};
use crate::model::vision::Resolution;
use crate::sim::engine::SimConfig;
use crate::util::rng::Rng;

/// Mixed chat + many-image traffic for cluster-scale runs.
#[derive(Debug, Clone)]
pub struct ClusterScaleWorkload {
    /// Fraction of requests carrying images, in [0, 1].
    pub vision_fraction: f64,
    /// Images per vision request.
    pub vision_images: u32,
    pub vision_prompt_tokens: u32,
    pub vision_output_tokens: u32,
    pub chat_prompt_tokens: u32,
    pub chat_output_tokens: u32,
    pub resolution: Resolution,
}

impl Default for ClusterScaleWorkload {
    fn default() -> Self {
        ClusterScaleWorkload {
            vision_fraction: 0.3,
            vision_images: 4,
            vision_prompt_tokens: 22,
            vision_output_tokens: 8,
            chat_prompt_tokens: 64,
            chat_output_tokens: 96,
            resolution: Resolution::four_k(),
        }
    }
}

impl ClusterScaleWorkload {
    /// The 64-instance reference topology (paper-shaped 5:2:1 ratio).
    pub fn topology64() -> Topology {
        Topology::new(40, 16, 8)
    }

    /// The reference simulator configuration for this workload: the
    /// 64-instance EPD cluster with the default batch/policy knobs.
    pub fn sim_config(spec: &LmmSpec, device: DeviceSpec) -> SimConfig {
        SimConfig::new(
            spec.clone(),
            device,
            EpdConfig::epd(Self::topology64(), 1, 1, 128),
        )
    }
}

impl Workload for ClusterScaleWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            t += rng.exp(rate.max(1e-9));
            let vision = rng.bool(self.vision_fraction.clamp(0.0, 1.0));
            let (prompt, images, output) = if vision {
                (self.vision_prompt_tokens, self.vision_images, self.vision_output_tokens)
            } else {
                (self.chat_prompt_tokens, 0, self.chat_output_tokens)
            };
            out.push(build_request(
                spec,
                i as u64,
                t,
                prompt,
                images,
                self.resolution,
                output.max(1),
            ));
        }
        out
    }

    fn name(&self) -> &'static str {
        "cluster-scale"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn mixes_chat_and_vision_deterministically() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let w = ClusterScaleWorkload::default();
        let mut rng = Rng::new(7);
        let reqs = w.generate(&spec, 10_000, 50.0, &mut rng);
        assert_eq!(reqs.len(), 10_000);
        let vision = reqs.iter().filter(|r| r.images > 0).count();
        let frac = vision as f64 / reqs.len() as f64;
        assert!((frac - 0.3).abs() < 0.02, "vision fraction {frac}");
        for pair in reqs.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival, "arrivals monotone");
        }
        for r in &reqs {
            if r.images > 0 {
                assert_eq!(r.images, 4);
                assert_eq!(r.output_tokens, 8);
            } else {
                assert_eq!(r.prompt_tokens, 64);
                assert_eq!(r.output_tokens, 96);
            }
        }
        // Same seed ⇒ identical stream.
        let mut rng2 = Rng::new(7);
        let again = w.generate(&spec, 10_000, 50.0, &mut rng2);
        for (a, b) in reqs.iter().zip(again.iter()) {
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
            assert_eq!(a.images, b.images);
        }
    }

    #[test]
    fn reference_cluster_is_64_instances() {
        let t = ClusterScaleWorkload::topology64();
        assert_eq!(t.total(), 64);
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let cfg = ClusterScaleWorkload::sim_config(&spec, DeviceSpec::a100());
        assert_eq!(cfg.epd.instances.len(), 64);
    }

    #[test]
    fn degenerate_fractions() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(3);
        let all_chat =
            ClusterScaleWorkload { vision_fraction: 0.0, ..Default::default() };
        assert!(all_chat.generate(&spec, 50, 10.0, &mut rng).iter().all(|r| r.images == 0));
        let all_vision =
            ClusterScaleWorkload { vision_fraction: 1.0, ..Default::default() };
        assert!(all_vision.generate(&spec, 50, 10.0, &mut rng).iter().all(|r| r.images == 4));
    }
}
