//! Phase-shifting workload: an encode-heavy many-image burst followed by
//! a long-decode chat tail — the regime where online reallocation
//! (§3.2.3 + §3.2.4) wins or loses SLO attainment. The burst saturates
//! the encode stage with multi-image 4K requests and short outputs; the
//! tail flips the bottleneck to decode with text-only prompts and long
//! outputs, so a topology provisioned for either phase starves in the
//! other.

use super::{build_request, synthetic::SyntheticWorkload, Workload};
use crate::core::request::Request;
use crate::model::spec::LmmSpec;
use crate::model::vision::Resolution;
use crate::util::rng::Rng;

/// Two [`SyntheticWorkload`] phases back to back.
#[derive(Debug, Clone)]
pub struct PhaseShiftWorkload {
    /// Phase 1: encode-heavy many-image burst.
    pub burst: SyntheticWorkload,
    /// Phase 2: long-decode chat tail.
    pub tail: SyntheticWorkload,
    /// Fraction of requests in the burst phase, in [0, 1].
    pub burst_fraction: f64,
    /// Burst arrivals run at `rate × burst_rate_factor` (many-image
    /// requests carry far more encode work per request, so a sustainable
    /// burst arrives slower than the text tail).
    pub burst_rate_factor: f64,
}

impl Default for PhaseShiftWorkload {
    fn default() -> Self {
        PhaseShiftWorkload {
            burst: SyntheticWorkload {
                prompt_tokens: 22,
                images_per_request: 4,
                resolution: Resolution::four_k(),
                output_tokens: 8,
                output_jitter: 0,
            },
            tail: SyntheticWorkload {
                prompt_tokens: 64,
                images_per_request: 0,
                resolution: Resolution::four_k(),
                output_tokens: 160,
                output_jitter: 0,
            },
            burst_fraction: 0.25,
            burst_rate_factor: 0.2,
        }
    }
}

impl Workload for PhaseShiftWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let n_burst = ((n as f64) * self.burst_fraction.clamp(0.0, 1.0)).round() as usize;
        let n_burst = n_burst.min(n);
        let burst_rate = (rate * self.burst_rate_factor).max(1e-9);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let (phase, r) = if i < n_burst {
                (&self.burst, burst_rate)
            } else {
                (&self.tail, rate)
            };
            t += rng.exp(r);
            out.push(build_request(
                spec,
                i as u64,
                t,
                phase.prompt_tokens,
                phase.images_per_request,
                phase.resolution,
                phase.output_tokens.max(1),
            ));
        }
        out
    }

    fn name(&self) -> &'static str {
        "phase-shift"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn two_phases_with_monotone_arrivals() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(5);
        let w = PhaseShiftWorkload::default();
        let reqs = w.generate(&spec, 100, 2.0, &mut rng);
        assert_eq!(reqs.len(), 100);
        let n_burst = reqs.iter().filter(|r| r.images > 0).count();
        assert_eq!(n_burst, 25, "burst_fraction 0.25 of 100");
        // The burst comes first, then the text tail.
        assert!(reqs[..25].iter().all(|r| r.images == 4 && r.output_tokens == 8));
        assert!(reqs[25..].iter().all(|r| r.images == 0 && r.output_tokens == 160));
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        // The burst arrives slower than the tail (rate factor 0.2).
        let burst_span = reqs[24].arrival - reqs[0].arrival;
        let tail_span = reqs[99].arrival - reqs[25].arrival;
        assert!(burst_span / 24.0 > tail_span / 74.0, "burst gaps are longer");
    }

    #[test]
    fn degenerate_fractions() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(6);
        let all_tail = PhaseShiftWorkload { burst_fraction: 0.0, ..Default::default() };
        assert!(all_tail
            .generate(&spec, 10, 1.0, &mut rng)
            .iter()
            .all(|r| r.images == 0));
        let all_burst = PhaseShiftWorkload { burst_fraction: 1.0, ..Default::default() };
        assert!(all_burst
            .generate(&spec, 10, 1.0, &mut rng)
            .iter()
            .all(|r| r.images == 4));
    }
}
