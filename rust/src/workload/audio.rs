//! Audio workload (Appendix A.1): ultravox-v0_3 serving with 24 audio
//! clips per request — an encode-intensive configuration. Each clip is one
//! encoder "tile" producing `tokens_per_tile` LLM tokens; resolution is
//! meaningless for audio, so a nominal value carries the clip count.

use super::{build_request, Workload};
use crate::core::request::Request;
use crate::model::spec::LmmSpec;
use crate::model::vision::Resolution;
use crate::util::rng::Rng;

/// Audio (ultravox) workload generator.
#[derive(Debug, Clone)]
pub struct AudioWorkload {
    pub clips_per_request: u32,
}

impl Default for AudioWorkload {
    fn default() -> Self {
        AudioWorkload { clips_per_request: 24 }
    }
}

impl Workload for AudioWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let arrivals = super::arrival::poisson_arrivals(n, rate, rng);
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let prompt = rng.range(10, 40) as u32;
                let out = rng.range(30, 120) as u32;
                // Audio clips: nominal 1-"pixel" resolution; clip count in
                // `images`. AudioClip tiling yields 1 tile per clip.
                build_request(
                    spec,
                    i as u64,
                    t,
                    prompt,
                    self.clips_per_request,
                    Resolution::new(1, 1),
                    out,
                )
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "audio"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn clip_counts_and_tokens() {
        let spec = LmmSpec::get(ModelId::UltravoxV03);
        let mut rng = Rng::new(6);
        let reqs = AudioWorkload::default().generate(&spec, 10, 1.0, &mut rng);
        for r in &reqs {
            assert_eq!(r.images, 24);
            assert_eq!(r.tiles_per_image, 1);
            // 24 clips × tokens_per_tile each.
            assert_eq!(
                r.total_mm_tokens(),
                24 * spec.vision.tokens_per_tile as u64
            );
            assert!((30..120).contains(&r.output_tokens));
        }
    }
}
