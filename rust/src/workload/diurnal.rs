//! Multi-day diurnal traffic with flash crowds: the arrival shape the
//! chaos/resilience benches replay at cluster scale. The request *mix* is
//! delegated to [`ClusterScaleWorkload`] (chat-dominated with a
//! many-image vision minority); this module only modulates the arrival
//! rate:
//!
//! - a smooth day/night cycle (`trough_factor` × the nominal rate at
//!   midnight, the full rate at midday, raised-cosine in between),
//! - plus `flash_crowds` seeded burst windows where the rate multiplies
//!   by `flash_factor` — the "viral moment" the reallocation planner has
//!   to absorb while a fault wave is in flight.
//!
//! Everything is a pure function of the struct's fields: the flash
//! windows come from their own seed (not the arrival RNG), so
//! [`DiurnalWorkload::rate_factor`] is inspectable and the same seed
//! replays the same trace bit-for-bit.

use super::{ClusterScaleWorkload, Workload};
use crate::core::request::Request;
use crate::model::spec::LmmSpec;
use crate::util::rng::Rng;

/// Diurnal (day/night) arrival modulation with seeded flash crowds over
/// the cluster-scale request mix.
#[derive(Debug, Clone)]
pub struct DiurnalWorkload {
    /// Request shape (chat/vision mix, token counts, resolution).
    pub base: ClusterScaleWorkload,
    /// Number of simulated days in the trace.
    pub days: u32,
    /// Seconds per (compressed) day.
    pub day_seconds: f64,
    /// Midnight rate as a fraction of the nominal rate, in (0, 1].
    pub trough_factor: f64,
    /// Flash-crowd windows scattered over the whole trace.
    pub flash_crowds: u32,
    /// Rate multiplier inside a flash window.
    pub flash_factor: f64,
    /// Flash window length, seconds.
    pub flash_duration: f64,
    /// Seed for flash-window placement (independent of the arrival RNG,
    /// so the windows are inspectable before generating anything).
    pub flash_seed: u64,
}

impl Default for DiurnalWorkload {
    fn default() -> Self {
        DiurnalWorkload {
            base: ClusterScaleWorkload::default(),
            days: 3,
            day_seconds: 120.0,
            trough_factor: 0.25,
            flash_crowds: 2,
            flash_factor: 4.0,
            flash_duration: 6.0,
            flash_seed: 0xD1A7,
        }
    }
}

impl DiurnalWorkload {
    /// Total trace span in seconds (`days × day_seconds`).
    pub fn span(&self) -> f64 {
        self.days as f64 * self.day_seconds
    }

    /// The seeded flash windows as `(start, end)` pairs, sorted by start.
    /// Pure function of `flash_seed`/`flash_crowds`/geometry.
    pub fn flash_windows(&self) -> Vec<(f64, f64)> {
        let span = self.span();
        let dur = self.flash_duration.max(0.0).min(span);
        let mut rng = Rng::new(self.flash_seed ^ 0xF1A5_4C40_3D00_0001);
        let mut out: Vec<(f64, f64)> = (0..self.flash_crowds)
            .map(|_| {
                let start = rng.uniform(0.0, (span - dur).max(0.0));
                (start, start + dur)
            })
            .collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }

    /// Instantaneous rate multiplier at virtual time `t`: the raised-
    /// cosine day cycle (trough at t ≡ 0 mod day, peak at midday) times
    /// the flash factor inside any flash window. Times past the last day
    /// keep cycling, so overshooting arrivals stay well-defined.
    pub fn rate_factor(&self, t: f64) -> f64 {
        let day = self.day_seconds.max(1e-9);
        let phase = (t.rem_euclid(day)) / day;
        let trough = self.trough_factor.clamp(0.0, 1.0);
        let mut f = trough
            + (1.0 - trough) * 0.5 * (1.0 - (2.0 * std::f64::consts::PI * phase).cos());
        for (s, e) in self.flash_windows() {
            if t >= s && t < e {
                f *= self.flash_factor.max(1.0);
            }
        }
        f
    }
}

impl Workload for DiurnalWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        // Rate-modulated arrival process: each gap is exponential at the
        // *current* modulated rate. (A stepwise approximation of the
        // non-homogeneous process — exact enough for traces whose gaps
        // are far shorter than the day cycle, and fully deterministic.)
        let windows = self.flash_windows();
        let day = self.day_seconds.max(1e-9);
        let trough = self.trough_factor.clamp(0.0, 1.0);
        let mut t = 0.0;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let phase = (t.rem_euclid(day)) / day;
            let mut f = trough
                + (1.0 - trough) * 0.5 * (1.0 - (2.0 * std::f64::consts::PI * phase).cos());
            for &(s, e) in &windows {
                if t >= s && t < e {
                    f *= self.flash_factor.max(1.0);
                }
            }
            t += rng.exp((rate * f).max(1e-9));
            let vision = rng.bool(self.base.vision_fraction.clamp(0.0, 1.0));
            let (prompt, images, output) = if vision {
                (
                    self.base.vision_prompt_tokens,
                    self.base.vision_images,
                    self.base.vision_output_tokens,
                )
            } else {
                (self.base.chat_prompt_tokens, 0, self.base.chat_output_tokens)
            };
            out.push(super::build_request(
                spec,
                i as u64,
                t,
                prompt,
                images,
                self.base.resolution,
                output.max(1),
            ));
        }
        out
    }

    fn name(&self) -> &'static str {
        "diurnal"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn same_seed_replays_bit_for_bit() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let w = DiurnalWorkload::default();
        let a = w.generate(&spec, 2_000, 20.0, &mut Rng::new(11));
        let b = w.generate(&spec, 2_000, 20.0, &mut Rng::new(11));
        assert_eq!(a.len(), 2_000);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.images, y.images);
            assert_eq!(x.output_tokens, y.output_tokens);
        }
        for pair in a.windows(2) {
            assert!(pair[1].arrival >= pair[0].arrival, "arrivals monotone");
        }
    }

    #[test]
    fn day_cycle_peaks_at_midday() {
        let w = DiurnalWorkload { flash_crowds: 0, ..Default::default() };
        let day = w.day_seconds;
        assert!((w.rate_factor(0.0) - w.trough_factor).abs() < 1e-9, "midnight = trough");
        assert!((w.rate_factor(0.5 * day) - 1.0).abs() < 1e-9, "midday = full rate");
        assert!(w.rate_factor(0.25 * day) > w.trough_factor);
        assert!(w.rate_factor(0.25 * day) < 1.0);
        // Cycles across days.
        assert!((w.rate_factor(2.5 * day) - 1.0).abs() < 1e-9);
        // Arrivals cluster at midday: the middle fifth of day one holds
        // more than the (trough-rate) first fifth.
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let reqs = w.generate(&spec, 5_000, 60.0, &mut Rng::new(5));
        let in_band = |lo: f64, hi: f64| {
            reqs.iter().filter(|r| r.arrival >= lo && r.arrival < hi).count()
        };
        let first = in_band(0.0, 0.2 * day);
        let mid = in_band(0.4 * day, 0.6 * day);
        assert!(mid > first, "midday band {mid} should out-arrive the trough band {first}");
    }

    #[test]
    fn flash_windows_are_seeded_and_in_span() {
        let w = DiurnalWorkload::default();
        let a = w.flash_windows();
        let b = w.flash_windows();
        assert_eq!(a, b, "pure function of the seed");
        assert_eq!(a.len(), 2);
        for &(s, e) in &a {
            assert!(s >= 0.0 && e <= w.span() + 1e-9);
            assert!((e - s - w.flash_duration).abs() < 1e-9);
        }
        // Inside a window the factor multiplies by flash_factor.
        let (s, e) = a[0];
        let t = 0.5 * (s + e);
        let calm = DiurnalWorkload { flash_crowds: 0, ..DiurnalWorkload::default() };
        let boosted = w.rate_factor(t) / calm.rate_factor(t);
        assert!(boosted >= w.flash_factor - 1e-9, "boost {boosted}");
        let seeded = DiurnalWorkload { flash_seed: 99, ..DiurnalWorkload::default() };
        assert_ne!(seeded.flash_windows(), a, "different seed, different windows");
    }
}
