//! Workload generators for the paper's four evaluation datasets.
//!
//! The real datasets (NextQA, Video-MME, the audio corpus) are not
//! redistributable here; per DESIGN.md's substitution table, the
//! generators reproduce the *statistics the serving system observes* —
//! token counts, frame/image counts, resolutions, output lengths and
//! Poisson arrivals — using the figures the paper itself publishes.

pub mod synthetic;
pub mod nextqa;
pub mod videomme;
pub mod audio;
pub mod arrival;
pub mod cluster_scale;
pub mod diurnal;
pub mod mixed_tenant;
pub mod phase_shift;
pub mod repeated_media;

pub use arrival::poisson_arrivals;
pub use cluster_scale::ClusterScaleWorkload;
pub use diurnal::DiurnalWorkload;
pub use mixed_tenant::MixedTenantWorkload;
pub use phase_shift::PhaseShiftWorkload;
pub use repeated_media::RepeatedMediaWorkload;
pub use synthetic::SyntheticWorkload;

use crate::core::request::{Priority, Request};
use crate::model::spec::LmmSpec;
use crate::model::vision::{mm_tokens_for_image, tiles_for_image, Resolution};
use crate::util::rng::Rng;

/// Common builder: materialize a request for `spec`, caching tiling math.
pub(crate) fn build_request(
    spec: &LmmSpec,
    id: u64,
    arrival: f64,
    prompt_tokens: u32,
    images: u32,
    resolution: Resolution,
    output_tokens: u32,
) -> Request {
    Request {
        id,
        arrival,
        prompt_tokens,
        images,
        resolution,
        output_tokens,
        tiles_per_image: tiles_for_image(spec, resolution),
        mm_tokens_per_image: mm_tokens_for_image(spec, resolution) as u32,
        media_hash: None,
        tenant: 0,
        class: Priority::Interactive,
        deadline: f64::INFINITY,
    }
}

/// A workload generator: yields a request list for a target model at a
/// given arrival rate.
pub trait Workload {
    /// Generate `n` requests with Poisson(rate) arrivals.
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request>;
    /// Short name for reports.
    fn name(&self) -> &'static str;
}
