//! NextQA-shaped workload (§4.1): video question answering. The paper's
//! sample of 100 requests had text prompts of 4–21 tokens (mean 11.42),
//! outputs of 1–7 tokens (mean 2.75), and 8 uniformly-sampled frames per
//! video at typical NextQA frame resolution (~640×480).

use super::{build_request, Workload};
use crate::core::request::Request;
use crate::model::spec::LmmSpec;
use crate::model::vision::Resolution;
use crate::util::rng::Rng;

/// NextQA-like trace generator.
#[derive(Debug, Clone)]
pub struct NextQaWorkload {
    pub frames: u32,
}

impl Default for NextQaWorkload {
    fn default() -> Self {
        NextQaWorkload { frames: 8 }
    }
}

/// Draw from a discrete triangular-ish distribution on `[lo, hi]` with the
/// given mean by mixture of two uniforms (simple moment matching).
fn bounded_mean_draw(rng: &mut Rng, lo: u32, hi: u32, mean: f64) -> u32 {
    // Mix U[lo, m] and U[m, hi] with weights that hit the target mean.
    let m = mean.round() as u32;
    let lo_mean = (lo + m) as f64 / 2.0;
    let hi_mean = (m + hi) as f64 / 2.0;
    let w = if hi_mean > lo_mean {
        ((mean - lo_mean) / (hi_mean - lo_mean)).clamp(0.0, 1.0)
    } else {
        0.5
    };
    if rng.bool(w) {
        rng.range(m as usize, hi as usize) as u32
    } else {
        rng.range(lo as usize, m as usize) as u32
    }
}

impl Workload for NextQaWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let arrivals = super::arrival::poisson_arrivals(n, rate, rng);
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let prompt = bounded_mean_draw(rng, 4, 21, 11.42);
                let out = bounded_mean_draw(rng, 1, 7, 2.75);
                build_request(
                    spec,
                    i as u64,
                    t,
                    prompt,
                    self.frames,
                    Resolution::new(640, 480),
                    out,
                )
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "nextqa"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn statistics_match_paper() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(3);
        let reqs = NextQaWorkload::default().generate(&spec, 5000, 1.0, &mut rng);
        let mean_prompt: f64 =
            reqs.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / reqs.len() as f64;
        let mean_out: f64 =
            reqs.iter().map(|r| r.output_tokens as f64).sum::<f64>() / reqs.len() as f64;
        assert!((mean_prompt - 11.42).abs() < 1.0, "prompt mean {mean_prompt}");
        assert!((mean_out - 2.75).abs() < 0.5, "output mean {mean_out}");
        for r in &reqs {
            assert!((4..=21).contains(&r.prompt_tokens));
            assert!((1..=7).contains(&r.output_tokens));
            assert_eq!(r.images, 8);
        }
    }
}
