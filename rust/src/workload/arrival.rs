//! Arrival-process generation (§4.1: "requests arrive following a Poisson
//! process with rate λ").

use crate::util::rng::Rng;

/// `n` arrival times of a Poisson process with rate `rate` (req/s),
/// starting after time 0. `rate == f64::INFINITY` yields all-at-once
/// arrivals at t = 0 (the offline batch setting of Appendix A.3).
pub fn poisson_arrivals(n: usize, rate: f64, rng: &mut Rng) -> Vec<f64> {
    if rate.is_infinite() {
        return vec![0.0; n];
    }
    assert!(rate > 0.0, "rate must be positive");
    let mut t = 0.0;
    (0..n)
        .map(|_| {
            t += rng.exp(rate);
            t
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrivals_are_increasing() {
        let mut rng = Rng::new(1);
        let a = poisson_arrivals(100, 2.0, &mut rng);
        assert_eq!(a.len(), 100);
        for w in a.windows(2) {
            assert!(w[1] > w[0]);
        }
    }

    #[test]
    fn mean_rate_matches() {
        let mut rng = Rng::new(2);
        let a = poisson_arrivals(20_000, 4.0, &mut rng);
        let empirical = a.len() as f64 / a.last().unwrap();
        assert!((empirical - 4.0).abs() < 0.15, "rate {empirical}");
    }

    #[test]
    fn offline_batch_all_at_zero() {
        let mut rng = Rng::new(3);
        let a = poisson_arrivals(10, f64::INFINITY, &mut rng);
        assert!(a.iter().all(|&t| t == 0.0));
    }
}
