//! The configurable synthetic workload (§4: prompt length, images per
//! request, resolution, output length all parameterized; defaults follow
//! §4.1 — 22-token prompts, 4032×3024 images, 10 output tokens).

use super::{build_request, Workload};
use crate::core::request::Request;
use crate::model::spec::LmmSpec;
use crate::model::vision::Resolution;
use crate::util::rng::Rng;

/// Synthetic multimodal workload.
#[derive(Debug, Clone)]
pub struct SyntheticWorkload {
    pub prompt_tokens: u32,
    pub images_per_request: u32,
    pub resolution: Resolution,
    pub output_tokens: u32,
    /// Optional jitter: when > 0, output length is uniform in
    /// `[output_tokens, output_tokens + output_jitter]`.
    pub output_jitter: u32,
}

impl Default for SyntheticWorkload {
    fn default() -> Self {
        SyntheticWorkload {
            prompt_tokens: 22,
            images_per_request: 2,
            resolution: Resolution::four_k(),
            output_tokens: 10,
            output_jitter: 0,
        }
    }
}

impl SyntheticWorkload {
    pub fn new(images_per_request: u32, output_tokens: u32) -> SyntheticWorkload {
        SyntheticWorkload {
            images_per_request,
            output_tokens,
            ..Default::default()
        }
    }
}

impl Workload for SyntheticWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let arrivals = super::arrival::poisson_arrivals(n, rate, rng);
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let out = if self.output_jitter > 0 {
                    self.output_tokens + rng.below(self.output_jitter as u64 + 1) as u32
                } else {
                    self.output_tokens
                };
                build_request(
                    spec,
                    i as u64,
                    t,
                    self.prompt_tokens,
                    self.images_per_request,
                    self.resolution,
                    out.max(1),
                )
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "synthetic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn generates_paper_defaults() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(1);
        let w = SyntheticWorkload::new(4, 10);
        let reqs = w.generate(&spec, 100, 1.0, &mut rng);
        assert_eq!(reqs.len(), 100);
        for r in &reqs {
            assert_eq!(r.prompt_tokens, 22);
            assert_eq!(r.images, 4);
            assert_eq!(r.output_tokens, 10);
            assert_eq!(r.tiles_per_image, 10); // MiniCPM @ 4K
            assert_eq!(r.mm_tokens_per_image, 640);
        }
    }

    #[test]
    fn jitter_varies_outputs() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(2);
        let mut w = SyntheticWorkload::new(1, 50);
        w.output_jitter = 100;
        let reqs = w.generate(&spec, 200, 1.0, &mut rng);
        let min = reqs.iter().map(|r| r.output_tokens).min().unwrap();
        let max = reqs.iter().map(|r| r.output_tokens).max().unwrap();
        assert!(min >= 50 && max <= 150 && max > min);
    }
}
