//! Mixed text + multimodal multi-tenant workload — the front-door
//! router's gate workload (`benches/perf_router_slo.rs`).
//!
//! The mix models a production LMM endpoint: a majority of short
//! text-only chat turns (which an EPD front door can route straight to
//! prefill, skipping encode entirely) interleaved with heavy multimodal
//! requests, submitted by a Zipf-skewed tenant population with a
//! batch-class fraction. Requests are authored as [`SubmitRequest`]
//! descriptors and lowered with [`SubmitRequest::to_sim_request`] — the
//! same typed front door the HTTP frontend uses, so the sim and the
//! engine exercise one surface.

use super::Workload;
use crate::api::SubmitRequest;
use crate::core::request::{Priority, Request};
use crate::model::spec::LmmSpec;
use crate::model::vision::Resolution;
use crate::util::rng::Rng;

/// Mixed text/MM multi-tenant workload.
#[derive(Debug, Clone)]
pub struct MixedTenantWorkload {
    /// Fraction of requests that are text-only (no images).
    pub text_fraction: f64,
    /// Fraction of requests submitted at the batch class.
    pub batch_fraction: f64,
    /// Tenant population; tenant ids are drawn Zipf(`zipf_s`) so low ids
    /// dominate (tenant 0 is the heaviest).
    pub tenants: u32,
    pub zipf_s: f64,
    /// Images attached to each multimodal request.
    pub images: u32,
    pub resolution: Resolution,
    /// Prompt length of multimodal requests (tokens).
    pub mm_prompt_tokens: u32,
    /// Extra prompt length of text-only requests (longer chat context).
    pub text_prompt_tokens: u32,
    /// Output lengths: text chat turns run longer than MM captioning.
    pub text_output_tokens: u32,
    pub mm_output_tokens: u32,
}

impl Default for MixedTenantWorkload {
    fn default() -> Self {
        MixedTenantWorkload {
            text_fraction: 0.6,
            batch_fraction: 0.25,
            tenants: 8,
            zipf_s: 1.1,
            images: 4,
            resolution: Resolution::four_k(),
            mm_prompt_tokens: 22,
            text_prompt_tokens: 96,
            text_output_tokens: 64,
            mm_output_tokens: 16,
        }
    }
}

impl Workload for MixedTenantWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let arrivals = super::arrival::poisson_arrivals(n, rate, rng);
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let tenant = (rng.zipf(self.tenants.max(1) as u64, self.zipf_s) - 1) as u32;
                let class = if rng.bool(self.batch_fraction) {
                    Priority::Batch
                } else {
                    Priority::Interactive
                };
                let text = rng.bool(self.text_fraction);
                let sub = if text {
                    SubmitRequest::new("")
                        .prompt_tokens(self.text_prompt_tokens)
                        .max_tokens(self.text_output_tokens)
                } else {
                    SubmitRequest::new("")
                        .prompt_tokens(self.mm_prompt_tokens)
                        .images(self.images)
                        .resolution(self.resolution)
                        .max_tokens(self.mm_output_tokens)
                };
                sub.tenant(tenant).priority(class).to_sim_request(spec, i as u64, t)
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "mixed-tenant"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn mix_matches_fractions() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(7);
        let w = MixedTenantWorkload::default();
        let reqs = w.generate(&spec, 1000, 2.0, &mut rng);
        assert_eq!(reqs.len(), 1000);
        let text = reqs.iter().filter(|r| r.images == 0).count();
        let batch = reqs.iter().filter(|r| r.class == Priority::Batch).count();
        assert!((500..=700).contains(&text), "text fraction ~0.6, got {text}");
        assert!((150..=350).contains(&batch), "batch fraction ~0.25, got {batch}");
        for r in &reqs {
            if r.images == 0 {
                assert_eq!(r.prompt_tokens, 96);
                assert_eq!(r.output_tokens, 64);
            } else {
                assert_eq!(r.images, 4);
                assert_eq!(r.output_tokens, 16);
            }
            assert!(r.tenant < 8);
        }
    }

    #[test]
    fn tenants_are_zipf_skewed() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(11);
        let reqs = MixedTenantWorkload::default().generate(&spec, 2000, 2.0, &mut rng);
        let mut counts = [0usize; 8];
        for r in &reqs {
            counts[r.tenant as usize] += 1;
        }
        assert!(
            counts[0] > counts[7] * 2,
            "tenant 0 should dominate: {counts:?}"
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let a = MixedTenantWorkload::default().generate(&spec, 50, 1.0, &mut Rng::new(3));
        let b = MixedTenantWorkload::default().generate(&spec, 50, 1.0, &mut Rng::new(3));
        assert_eq!(a, b);
    }
}
