//! Repeated-media workload: Zipf-distributed media popularity.
//!
//! Production multimodal traffic is not all-unique: hot thumbnails,
//! shared video frames and few-shot prompt templates recur across
//! requests (the observation behind EPD-Serve's cross-request encoder
//! cache and ElasticMM's encode-pool elasticity). This generator models
//! that with a fixed catalog of media items whose request popularity
//! follows Zipf(`s`) — rank 1 is the hottest item — plus an optional
//! fraction of never-repeated one-off media.
//!
//! Each generated request carries `media_hash = Some(content hash of its
//! catalog item)`, which is what arms the cross-request encoder cache in
//! both the simulator and the real engine; the remaining shape (prompt
//! length, images, resolution, output length) matches the §4.1 synthetic
//! workload.

use super::{build_request, Workload};
use crate::cache::content_hash_words;
use crate::core::request::Request;
use crate::model::spec::LmmSpec;
use crate::model::vision::Resolution;
use crate::util::rng::Rng;

/// Domain-separation tag so catalog hashes cannot collide with other
/// `content_hash_words` users (e.g. the engine's (seed, images) hashes).
const CATALOG_TAG: u64 = 0x5EED_0CA7_A106_0000;

/// Zipf-popularity repeated-media workload.
#[derive(Debug, Clone)]
pub struct RepeatedMediaWorkload {
    /// Text prompt length (paper default: 22).
    pub prompt_tokens: u32,
    /// Images per request (all drawn from the same catalog item —
    /// modelling e.g. one shared template or one re-sent photo set).
    pub images_per_request: u32,
    pub resolution: Resolution,
    pub output_tokens: u32,
    /// Distinct media items in the catalog.
    pub catalog_size: u64,
    /// Zipf exponent over catalog ranks (s > 0 skews toward rank 1;
    /// s = 0 degenerates to uniform popularity).
    pub zipf_s: f64,
    /// Fraction of requests carrying fresh, never-repeated media
    /// (cold-path traffic mixed into the hot catalog).
    pub unique_frac: f64,
}

impl Default for RepeatedMediaWorkload {
    fn default() -> Self {
        RepeatedMediaWorkload {
            prompt_tokens: 22,
            images_per_request: 2,
            resolution: Resolution::four_k(),
            output_tokens: 10,
            catalog_size: 50,
            zipf_s: 1.1,
            unique_frac: 0.0,
        }
    }
}

impl RepeatedMediaWorkload {
    pub fn new(catalog_size: u64, zipf_s: f64) -> RepeatedMediaWorkload {
        RepeatedMediaWorkload {
            catalog_size: catalog_size.max(1),
            zipf_s,
            ..Default::default()
        }
    }

    /// Content hash of catalog item `rank` (1-based Zipf rank).
    pub fn item_hash(rank: u64) -> u64 {
        content_hash_words(&[CATALOG_TAG, rank])
    }
}

impl Workload for RepeatedMediaWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let arrivals = super::arrival::poisson_arrivals(n, rate, rng);
        let mut next_unique = 0u64;
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let mut r = build_request(
                    spec,
                    i as u64,
                    t,
                    self.prompt_tokens,
                    self.images_per_request,
                    self.resolution,
                    self.output_tokens.max(1),
                );
                let hash = if self.unique_frac > 0.0 && rng.bool(self.unique_frac) {
                    next_unique += 1;
                    // One-off media: unique hash, tagged separately from
                    // the catalog so it can never alias a hot item.
                    content_hash_words(&[CATALOG_TAG ^ u64::MAX, next_unique])
                } else {
                    Self::item_hash(rng.zipf(self.catalog_size, self.zipf_s))
                };
                if r.images > 0 {
                    r.media_hash = Some(hash);
                }
                r
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "repeated-media"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;
    use std::collections::HashMap;

    #[test]
    fn popularity_is_zipf_skewed() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(5);
        let w = RepeatedMediaWorkload::new(20, 1.2);
        let reqs = w.generate(&spec, 4000, 1.0, &mut rng);
        let mut counts: HashMap<u64, usize> = HashMap::new();
        for r in &reqs {
            *counts.entry(r.media_hash.unwrap()).or_default() += 1;
        }
        assert!(counts.len() <= 20, "bounded by the catalog");
        let hottest = *counts.get(&RepeatedMediaWorkload::item_hash(1)).unwrap_or(&0);
        let coldest = *counts.get(&RepeatedMediaWorkload::item_hash(20)).unwrap_or(&0);
        assert!(
            hottest > 5 * coldest.max(1),
            "rank 1 ({hottest}) must dominate rank 20 ({coldest})"
        );
    }

    #[test]
    fn unique_frac_injects_cold_traffic() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(6);
        let mut w = RepeatedMediaWorkload::new(5, 1.0);
        w.unique_frac = 0.5;
        let reqs = w.generate(&spec, 1000, 1.0, &mut rng);
        let mut counts: HashMap<u64, usize> = HashMap::new();
        for r in &reqs {
            *counts.entry(r.media_hash.unwrap()).or_default() += 1;
        }
        let singletons = counts.values().filter(|&&c| c == 1).count();
        assert!(
            (350..=650).contains(&singletons),
            "~half the requests are one-off media ({singletons})"
        );
    }

    #[test]
    fn deterministic_and_shaped_like_synthetic() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let w = RepeatedMediaWorkload::default();
        let a = w.generate(&spec, 50, 1.0, &mut Rng::new(9));
        let b = w.generate(&spec, 50, 1.0, &mut Rng::new(9));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.media_hash, y.media_hash);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.prompt_tokens, 22);
            assert_eq!(x.images, 2);
            assert!(x.media_hash.is_some());
        }
        assert_eq!(w.name(), "repeated-media");
    }
}
