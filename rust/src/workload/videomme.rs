//! Video-MME-shaped workload (§4.1): multiple-choice video QA across short
//! / medium / long videos. Following the paper's setup, each video is
//! represented by a configurable number of uniformly sampled frames (64 by
//! default — the MiniCPM leaderboard configuration; Table 1 sweeps
//! {8, 16, 32, 64}). Multiple-choice answers are short (1–4 tokens);
//! prompts carry the question plus options (~40–120 tokens).

use super::{build_request, Workload};
use crate::core::request::Request;
use crate::model::spec::LmmSpec;
use crate::model::vision::Resolution;
use crate::util::rng::Rng;

/// Video-MME-like trace generator.
#[derive(Debug, Clone)]
pub struct VideoMmeWorkload {
    pub frames: u32,
}

impl Default for VideoMmeWorkload {
    fn default() -> Self {
        VideoMmeWorkload { frames: 64 }
    }
}

impl VideoMmeWorkload {
    pub fn with_frames(frames: u32) -> VideoMmeWorkload {
        VideoMmeWorkload { frames }
    }
}

impl Workload for VideoMmeWorkload {
    fn generate(&self, spec: &LmmSpec, n: usize, rate: f64, rng: &mut Rng) -> Vec<Request> {
        let arrivals = super::arrival::poisson_arrivals(n, rate, rng);
        arrivals
            .into_iter()
            .enumerate()
            .map(|(i, t)| {
                let prompt = rng.range(40, 120) as u32;
                let out = rng.range(1, 4) as u32;
                // Video frames decode at sub-HD resolution.
                build_request(
                    spec,
                    i as u64,
                    t,
                    prompt,
                    self.frames,
                    Resolution::new(480, 360),
                    out,
                )
            })
            .collect()
    }

    fn name(&self) -> &'static str {
        "video-mme"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    #[test]
    fn frame_sweep_configs() {
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(4);
        for frames in [8u32, 16, 32, 64] {
            let reqs = VideoMmeWorkload::with_frames(frames).generate(&spec, 10, 1.0, &mut rng);
            assert!(reqs.iter().all(|r| r.images == frames));
        }
    }

    #[test]
    fn frames_are_single_tile_for_minicpm() {
        // 480×360 < 448² pixels → 1 slice per frame for MiniCPM.
        let spec = LmmSpec::get(ModelId::MiniCpmV26);
        let mut rng = Rng::new(5);
        let reqs = VideoMmeWorkload::default().generate(&spec, 5, 1.0, &mut rng);
        assert!(reqs.iter().all(|r| r.tiles_per_image == 1));
        // 64 frames × 64 tokens = 4096 MM tokens per request.
        assert_eq!(reqs[0].total_mm_tokens(), 64 * 64);
    }
}
