fn main() { epdserve::cli::run(); }
