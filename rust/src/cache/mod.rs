//! Paged cache management (§3.2.1 and §E.1).
//!
//! All three caches follow vLLM-style paging: fixed-size blocks handed out
//! from a free list, per-request block tables, O(1) allocate/free. The
//! [`mm_block_manager::MmBlockManager`] is the paper's contribution — a
//! paged cache for *multimodal* tokens that exists on both the encode and
//! prefill instances and backs the asynchronous EP token transfer. The
//! [`encoder_cache::EncoderCache`] extends it *across* requests: a
//! content-addressed LRU that lets a request whose media was seen before
//! skip the encode stage entirely.

pub mod block;
pub mod encoder_cache;
pub mod kv_block_manager;
pub mod mm_block_manager;

pub use block::{BlockId, BlockPool};
pub use encoder_cache::{content_hash, content_hash_words, ContentHash, EncoderCache, EncoderCacheStats};
pub use kv_block_manager::KvBlockManager;
pub use mm_block_manager::{MmBlockManager, MmEntryState};
