//! Paged cache management (§3.2.1 and §E.1).
//!
//! Both caches follow vLLM-style paging: fixed-size blocks handed out from
//! a free list, per-request block tables, O(1) allocate/free. The
//! [`mm_block_manager::MmBlockManager`] is the paper's contribution — a
//! paged cache for *multimodal* tokens that exists on both the encode and
//! prefill instances and backs the asynchronous EP token transfer.

pub mod block;
pub mod kv_block_manager;
pub mod mm_block_manager;

pub use block::{BlockId, BlockPool};
pub use kv_block_manager::KvBlockManager;
pub use mm_block_manager::{MmBlockManager, MmEntryState};
