//! Paged KV-cache manager: per-request block tables over a [`BlockPool`],
//! with incremental growth during decode (one block at a time as the
//! sequence crosses block boundaries) — the vLLM PagedAttention scheme the
//! paper builds on (§E.1: block size 16, max 2048 blocks/request).

use std::collections::HashMap;

use super::block::{BlockId, BlockPool};
use crate::core::request::RequestId;

/// KV-cache block manager for one instance.
#[derive(Debug, Clone)]
pub struct KvBlockManager {
    pool: BlockPool,
    /// Per-request block table and current token count.
    tables: HashMap<RequestId, KvEntry>,
    /// §E.1: at most this many blocks per request.
    max_blocks_per_request: u32,
}

#[derive(Debug, Clone)]
struct KvEntry {
    blocks: Vec<BlockId>,
    tokens: u64,
}

impl KvBlockManager {
    pub fn new(num_blocks: u32, block_tokens: u32, max_blocks_per_request: u32) -> KvBlockManager {
        KvBlockManager {
            pool: BlockPool::new(num_blocks, block_tokens),
            tables: HashMap::new(),
            max_blocks_per_request,
        }
    }

    /// Build a manager sized to `capacity_tokens` of KV cache.
    pub fn with_capacity_tokens(capacity_tokens: u64, block_tokens: u32) -> KvBlockManager {
        let blocks = (capacity_tokens / block_tokens as u64) as u32;
        KvBlockManager::new(blocks, block_tokens, 2048)
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Can a sequence of `tokens` tokens be admitted for `req`?
    pub fn can_admit(&self, tokens: u64) -> bool {
        let need = self.pool.blocks_for_tokens(tokens);
        need <= self.max_blocks_per_request && self.pool.can_alloc(need)
    }

    /// Admit a request with an initial `tokens`-token sequence (prefill
    /// output). Returns false (and allocates nothing) when it doesn't fit.
    pub fn admit(&mut self, req: RequestId, tokens: u64) -> bool {
        assert!(!self.tables.contains_key(&req), "request {req} already admitted");
        let need = self.pool.blocks_for_tokens(tokens);
        if need > self.max_blocks_per_request {
            return false;
        }
        match self.pool.alloc_n(need) {
            Some(blocks) => {
                self.tables.insert(req, KvEntry { blocks, tokens });
                true
            }
            None => false,
        }
    }

    /// Append one generated token; allocates a new block when the sequence
    /// crosses a block boundary. Returns false on OOM or per-request cap
    /// (caller must preempt/evict).
    pub fn append_token(&mut self, req: RequestId) -> bool {
        let block_tokens = self.pool.block_tokens() as u64;
        // Compute need first to avoid holding a &mut borrow across alloc.
        let (needs_block, at_cap) = match self.tables.get(&req) {
            Some(e) => (
                e.tokens % block_tokens == 0 && e.tokens > 0 || e.blocks.is_empty(),
                e.blocks.len() as u32 >= self.max_blocks_per_request,
            ),
            None => panic!("append_token for unknown request {req}"),
        };
        if needs_block {
            if at_cap {
                return false;
            }
            match self.pool.alloc() {
                Some(b) => self.tables.get_mut(&req).unwrap().blocks.push(b),
                None => return false,
            }
        }
        self.tables.get_mut(&req).unwrap().tokens += 1;
        true
    }

    /// Release all blocks of a finished/preempted request.
    pub fn release(&mut self, req: RequestId) {
        if let Some(entry) = self.tables.remove(&req) {
            self.pool.free_all(&entry.blocks);
        }
    }

    /// Transfer ownership of a request's KV blocks *out* of this manager
    /// (PD migration: the source side frees after the destination confirms;
    /// this models the confirm+free step). Returns the token count moved.
    pub fn migrate_out(&mut self, req: RequestId) -> Option<u64> {
        let entry = self.tables.remove(&req)?;
        self.pool.free_all(&entry.blocks);
        Some(entry.tokens)
    }

    /// Accept a migrated-in request with `tokens` of KV already computed.
    pub fn migrate_in(&mut self, req: RequestId, tokens: u64) -> bool {
        self.admit(req, tokens)
    }

    pub fn tokens_of(&self, req: RequestId) -> Option<u64> {
        self.tables.get(&req).map(|e| e.tokens)
    }

    pub fn blocks_of(&self, req: RequestId) -> Option<&[BlockId]> {
        self.tables.get(&req).map(|e| e.blocks.as_slice())
    }

    pub fn active_requests(&self) -> usize {
        self.tables.len()
    }

    pub fn utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// Release everything (role switch away from an LLM stage).
    pub fn clear(&mut self) {
        let reqs: Vec<RequestId> = self.tables.keys().copied().collect();
        for r in reqs {
            self.release(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_and_release() {
        let mut kv = KvBlockManager::new(8, 16, 2048);
        assert!(kv.admit(1, 33)); // 3 blocks
        assert_eq!(kv.blocks_of(1).unwrap().len(), 3);
        assert_eq!(kv.pool().free_blocks(), 5);
        kv.release(1);
        assert_eq!(kv.pool().free_blocks(), 8);
        assert_eq!(kv.active_requests(), 0);
    }

    #[test]
    fn admit_fails_clean_when_full() {
        let mut kv = KvBlockManager::new(4, 16, 2048);
        assert!(kv.admit(1, 48)); // 3 blocks
        assert!(!kv.admit(2, 32)); // needs 2, only 1 free
        assert_eq!(kv.pool().free_blocks(), 1, "failed admit must not leak");
        assert!(kv.admit(3, 10)); // 1 block fits
    }

    #[test]
    fn append_allocates_at_boundary() {
        let mut kv = KvBlockManager::new(4, 4, 2048);
        assert!(kv.admit(1, 4)); // exactly one full block
        assert_eq!(kv.blocks_of(1).unwrap().len(), 1);
        assert!(kv.append_token(1)); // crosses boundary → second block
        assert_eq!(kv.blocks_of(1).unwrap().len(), 2);
        assert_eq!(kv.tokens_of(1), Some(5));
        for _ in 0..3 {
            assert!(kv.append_token(1)); // fills block 2 (6,7,8)
        }
        assert_eq!(kv.blocks_of(1).unwrap().len(), 2);
    }

    #[test]
    fn append_oom_detected() {
        let mut kv = KvBlockManager::new(1, 4, 2048);
        assert!(kv.admit(1, 4));
        assert!(!kv.append_token(1), "no block available for growth");
        // Token count unchanged on failure.
        assert_eq!(kv.tokens_of(1), Some(4));
    }

    #[test]
    fn per_request_cap_enforced() {
        let mut kv = KvBlockManager::new(100, 4, 2);
        assert!(!kv.admit(1, 100), "needs 25 blocks > cap 2");
        assert!(kv.admit(1, 8));
        assert!(!kv.append_token(1), "cap reached");
    }

    #[test]
    fn migration_conserves_blocks() {
        let mut src = KvBlockManager::new(8, 16, 2048);
        let mut dst = KvBlockManager::new(8, 16, 2048);
        assert!(src.admit(7, 40));
        let moved = src.migrate_out(7).unwrap();
        assert_eq!(moved, 40);
        assert_eq!(src.pool().free_blocks(), 8);
        assert!(dst.migrate_in(7, moved));
        assert_eq!(dst.tokens_of(7), Some(40));
    }

    #[test]
    fn clear_releases_everything() {
        let mut kv = KvBlockManager::new(16, 16, 2048);
        for r in 0..4 {
            assert!(kv.admit(r, 20));
        }
        kv.clear();
        assert_eq!(kv.pool().free_blocks(), 16);
    }
}
