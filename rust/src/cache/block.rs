//! The shared block pool: a fixed number of fixed-size blocks with an O(1)
//! free-list allocator. Underlies both the KV and MM block managers.

/// Index of a cache block within a pool.
pub type BlockId = u32;

/// A pool of `num_blocks` equally-sized blocks.
///
/// Invariants (checked by the property tests in `tests/`):
/// - every block is either free or allocated, never both;
/// - `free_blocks() + allocated_blocks() == num_blocks()` always;
/// - a block returned by [`BlockPool::alloc`] is not handed out again until
///   freed.
#[derive(Debug, Clone)]
pub struct BlockPool {
    num_blocks: u32,
    block_tokens: u32,
    /// Free-list as a stack of block ids.
    free: Vec<BlockId>,
    /// Allocation bitmap for debug validation.
    allocated: Vec<bool>,
}

impl BlockPool {
    /// Create a pool of `num_blocks` blocks of `block_tokens` tokens each.
    pub fn new(num_blocks: u32, block_tokens: u32) -> BlockPool {
        assert!(block_tokens > 0);
        BlockPool {
            num_blocks,
            block_tokens,
            free: (0..num_blocks).rev().collect(),
            allocated: vec![false; num_blocks as usize],
        }
    }

    pub fn num_blocks(&self) -> u32 {
        self.num_blocks
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn allocated_blocks(&self) -> u32 {
        self.num_blocks - self.free_blocks()
    }

    /// Blocks needed to hold `tokens` tokens.
    pub fn blocks_for_tokens(&self, tokens: u64) -> u32 {
        tokens.div_ceil(self.block_tokens as u64) as u32
    }

    /// Can `n` blocks be allocated right now?
    pub fn can_alloc(&self, n: u32) -> bool {
        self.free_blocks() >= n
    }

    /// Allocate one block. `None` when exhausted.
    pub fn alloc(&mut self) -> Option<BlockId> {
        let id = self.free.pop()?;
        debug_assert!(!self.allocated[id as usize], "double allocation of {id}");
        self.allocated[id as usize] = true;
        Some(id)
    }

    /// Allocate `n` blocks atomically: either all or none.
    pub fn alloc_n(&mut self, n: u32) -> Option<Vec<BlockId>> {
        if !self.can_alloc(n) {
            return None;
        }
        Some((0..n).map(|_| self.alloc().unwrap()).collect())
    }

    /// Return a block to the pool.
    ///
    /// # Panics
    /// On double-free or out-of-range ids — these are always bugs in the
    /// caller and must not be absorbed silently.
    pub fn free(&mut self, id: BlockId) {
        assert!(id < self.num_blocks, "free of out-of-range block {id}");
        assert!(self.allocated[id as usize], "double free of block {id}");
        self.allocated[id as usize] = false;
        self.free.push(id);
    }

    /// Free a batch of blocks.
    pub fn free_all(&mut self, ids: &[BlockId]) {
        for &id in ids {
            self.free(id);
        }
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.num_blocks == 0 {
            return 0.0;
        }
        self.allocated_blocks() as f64 / self.num_blocks as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_cycle() {
        let mut p = BlockPool::new(4, 16);
        assert_eq!(p.free_blocks(), 4);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.allocated_blocks(), 2);
        p.free(a);
        assert_eq!(p.free_blocks(), 3);
        let c = p.alloc().unwrap();
        assert_ne!(c, b, "b is still live");
    }

    #[test]
    fn exhaustion_returns_none() {
        let mut p = BlockPool::new(2, 16);
        assert!(p.alloc().is_some());
        assert!(p.alloc().is_some());
        assert!(p.alloc().is_none());
        assert!(p.alloc_n(1).is_none());
    }

    #[test]
    fn alloc_n_atomic() {
        let mut p = BlockPool::new(3, 16);
        assert!(p.alloc_n(4).is_none());
        assert_eq!(p.free_blocks(), 3, "failed alloc_n must not leak");
        let blocks = p.alloc_n(3).unwrap();
        assert_eq!(blocks.len(), 3);
        assert_eq!(p.free_blocks(), 0);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut p = BlockPool::new(2, 16);
        let a = p.alloc().unwrap();
        p.free(a);
        p.free(a);
    }

    #[test]
    fn blocks_for_tokens_rounds_up() {
        let p = BlockPool::new(10, 16);
        assert_eq!(p.blocks_for_tokens(0), 0);
        assert_eq!(p.blocks_for_tokens(1), 1);
        assert_eq!(p.blocks_for_tokens(16), 1);
        assert_eq!(p.blocks_for_tokens(17), 2);
    }

    #[test]
    fn conservation_under_random_ops() {
        use crate::util::rng::Rng;
        let mut p = BlockPool::new(64, 16);
        let mut live: Vec<BlockId> = Vec::new();
        let mut rng = Rng::new(42);
        for _ in 0..10_000 {
            if rng.bool(0.5) && !live.is_empty() {
                let i = rng.below(live.len() as u64) as usize;
                p.free(live.swap_remove(i));
            } else if let Some(b) = p.alloc() {
                live.push(b);
            }
            assert_eq!(p.allocated_blocks() as usize, live.len());
            assert_eq!(p.free_blocks() + p.allocated_blocks(), 64);
        }
    }
}
