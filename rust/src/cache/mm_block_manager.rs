//! The MMBlockManager of §3.2.1: a paged cache for multimodal tokens that
//! exists on both encode and prefill instances.
//!
//! Lifecycle on the encode side: blocks are **pre-allocated** when a
//! request is scheduled (based on its tile count), filled as tiles finish,
//! then held until the asynchronous EP transfer is confirmed, at which
//! point they are freed ("once the transfer is confirmed, the encoding
//! cache entries are cleared to free memory"). On the prefill side blocks
//! are allocated when the transfer begins and freed after prefill consumes
//! them. With IRP a request's tokens arrive as independent shards that are
//! aligned and merged once all shards landed (§3.2.2).

use std::collections::HashMap;

use super::block::{BlockId, BlockPool};
use crate::core::request::RequestId;

/// State of a request's MM-cache entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmEntryState {
    /// Blocks reserved, encoding in progress (encode side).
    Filling,
    /// All tokens present, awaiting/undergoing EP transfer (encode side) or
    /// arriving shards (prefill side).
    Ready,
    /// All shards arrived and merged (prefill side); consumable by prefill.
    Merged,
}

#[derive(Debug, Clone)]
struct MmEntry {
    blocks: Vec<BlockId>,
    tokens: u64,
    state: MmEntryState,
    /// IRP: shards expected / arrived (1/1 for non-IRP requests).
    shards_expected: u32,
    shards_arrived: u32,
}

/// Paged multimodal-token cache for one instance.
#[derive(Debug, Clone)]
pub struct MmBlockManager {
    pool: BlockPool,
    entries: HashMap<RequestId, MmEntry>,
}

impl MmBlockManager {
    pub fn new(num_blocks: u32, block_tokens: u32) -> MmBlockManager {
        MmBlockManager {
            pool: BlockPool::new(num_blocks, block_tokens),
            entries: HashMap::new(),
        }
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    /// Pre-allocate blocks for a request that will produce `tokens` MM
    /// tokens in `shards` independent shards (IRP fan-out; 1 = whole
    /// request). Returns false without allocating when the cache is full.
    pub fn reserve(&mut self, req: RequestId, tokens: u64, shards: u32) -> bool {
        assert!(shards >= 1);
        assert!(!self.entries.contains_key(&req), "request {req} already reserved");
        let need = self.pool.blocks_for_tokens(tokens);
        match self.pool.alloc_n(need) {
            Some(blocks) => {
                self.entries.insert(
                    req,
                    MmEntry {
                        blocks,
                        tokens,
                        state: MmEntryState::Filling,
                        shards_expected: shards,
                        shards_arrived: 0,
                    },
                );
                true
            }
            None => false,
        }
    }

    /// Mark one shard's tokens as produced/arrived. Returns the new state.
    /// When all shards are in, the entry becomes `Ready` (encode side
    /// semantics) — callers on the prefill side then call [`Self::merge`].
    pub fn shard_done(&mut self, req: RequestId) -> MmEntryState {
        let e = self
            .entries
            .get_mut(&req)
            .unwrap_or_else(|| panic!("shard_done for unknown request {req}"));
        assert!(e.shards_arrived < e.shards_expected, "extra shard for {req}");
        e.shards_arrived += 1;
        if e.shards_arrived == e.shards_expected {
            e.state = MmEntryState::Ready;
        }
        e.state
    }

    /// Align/merge a Ready entry (prefill side, §3.2.2): all patch-level
    /// tokens are projected and concatenated in request order.
    pub fn merge(&mut self, req: RequestId) {
        let e = self.entries.get_mut(&req).expect("merge of unknown request");
        assert_eq!(e.state, MmEntryState::Ready, "merge before all shards arrived");
        e.state = MmEntryState::Merged;
    }

    /// Free a request's blocks (encode side: after transfer confirmation;
    /// prefill side: after prefill consumed the tokens).
    pub fn release(&mut self, req: RequestId) {
        if let Some(e) = self.entries.remove(&req) {
            self.pool.free_all(&e.blocks);
        }
    }

    pub fn state_of(&self, req: RequestId) -> Option<MmEntryState> {
        self.entries.get(&req).map(|e| e.state)
    }

    pub fn tokens_of(&self, req: RequestId) -> Option<u64> {
        self.entries.get(&req).map(|e| e.tokens)
    }

    pub fn can_reserve(&self, tokens: u64) -> bool {
        self.pool.can_alloc(self.pool.blocks_for_tokens(tokens))
    }

    pub fn active_requests(&self) -> usize {
        self.entries.len()
    }

    pub fn utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// Drop everything (role switch away from a stage that owns MM cache).
    pub fn clear(&mut self) {
        let reqs: Vec<RequestId> = self.entries.keys().copied().collect();
        for r in reqs {
            self.release(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reserve_fill_release() {
        let mut mm = MmBlockManager::new(8, 64);
        assert!(mm.reserve(1, 128, 1)); // 2 blocks
        assert_eq!(mm.state_of(1), Some(MmEntryState::Filling));
        assert_eq!(mm.shard_done(1), MmEntryState::Ready);
        mm.release(1);
        assert_eq!(mm.pool().free_blocks(), 8);
    }

    #[test]
    fn irp_shards_accumulate() {
        let mut mm = MmBlockManager::new(16, 64);
        assert!(mm.reserve(5, 640, 4)); // 4-way IRP
        assert_eq!(mm.shard_done(5), MmEntryState::Filling);
        assert_eq!(mm.shard_done(5), MmEntryState::Filling);
        assert_eq!(mm.shard_done(5), MmEntryState::Filling);
        assert_eq!(mm.shard_done(5), MmEntryState::Ready);
        mm.merge(5);
        assert_eq!(mm.state_of(5), Some(MmEntryState::Merged));
    }

    #[test]
    #[should_panic(expected = "merge before all shards")]
    fn merge_requires_ready() {
        let mut mm = MmBlockManager::new(16, 64);
        mm.reserve(5, 640, 4);
        mm.shard_done(5);
        mm.merge(5);
    }

    #[test]
    fn reserve_fails_clean_when_full() {
        let mut mm = MmBlockManager::new(2, 64);
        assert!(mm.reserve(1, 128, 1));
        assert!(!mm.reserve(2, 64, 1));
        assert_eq!(mm.pool().free_blocks(), 0);
        assert_eq!(mm.active_requests(), 1);
    }

    #[test]
    fn release_then_reuse() {
        let mut mm = MmBlockManager::new(2, 64);
        assert!(mm.reserve(1, 128, 1));
        mm.release(1);
        assert!(mm.reserve(2, 128, 1), "blocks reusable after release");
    }

    #[test]
    fn clear_frees_all() {
        let mut mm = MmBlockManager::new(8, 64);
        mm.reserve(1, 64, 1);
        mm.reserve(2, 64, 2);
        mm.clear();
        assert_eq!(mm.pool().free_blocks(), 8);
        assert_eq!(mm.active_requests(), 0);
    }
}
