//! Cross-request, content-addressed encoder-output cache.
//!
//! The §3.2.1 MM cache ([`super::MmBlockManager`]) is *per-request*: its
//! blocks are freed the moment the EP transfer is confirmed, so two
//! requests carrying the same image (hot thumbnails, shared video frames,
//! few-shot prompt templates) pay the full preprocess+encode cost twice.
//! This module adds the layer follow-up systems (EPD-Serve's flexible
//! encoder-cache transfer, ElasticMM's elastic multimodal parallelism)
//! identify as the next TTFT/encode-capacity win: an LRU cache keyed by a
//! *content hash* of the media payload, holding the encoder's output
//! tokens across requests.
//!
//! Design:
//!
//! - Entries are backed by ref-counted [`BlockPool`] blocks, so capacity
//!   accounting matches the paged MM cache it sits beside.
//! - A hit **pins** the entry (refcount +1) for the duration of its use —
//!   pinned entries are never evicted (enforced by a property test in
//!   `tests/property_cache.rs`). Consumers unpin after the EP transfer is
//!   confirmed (simulator) or after the prefill job is enqueued (engine),
//!   and on request abort.
//! - A miss encodes as usual, then **populates** the cache at transfer
//!   confirmation instead of freeing, evicting least-recently-used
//!   *unpinned* entries to make room.
//! - The engine variant stores the actual MM token vector as a shared
//!   payload ([`std::sync::Arc`]); the simulator stores accounting only.

use std::collections::HashMap;
use std::sync::Arc;

use super::block::{BlockId, BlockPool};

/// Content address of a media item: a 64-bit digest of its bytes.
pub type ContentHash = u64;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// FNV-1a over raw bytes — the admission-time content hash. Not
/// cryptographic: collisions only cause a (deterministic) wrong-token
/// reuse in this reproduction, never memory unsafety; a production system
/// would use a 128/256-bit digest here.
pub fn content_hash(bytes: &[u8]) -> ContentHash {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over a sequence of words (media ids, seeds, image counts).
pub fn content_hash_words(words: &[u64]) -> ContentHash {
    let mut h = FNV_OFFSET;
    for &w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Hit/miss/eviction counters, exported into [`crate::sim::SimOutcome`]
/// and the engine's `/metrics` report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EncoderCacheStats {
    /// Lookups that found a cached entry.
    pub hits: u64,
    /// Lookups that found nothing.
    pub misses: u64,
    /// Entries admitted (first insertion of a hash).
    pub insertions: u64,
    /// Entries evicted to make room.
    pub evictions: u64,
    /// Insertions rejected because unpinned capacity was insufficient.
    pub rejected: u64,
}

impl EncoderCacheStats {
    /// Hits over lookups, in [0, 1]; 0 when no lookups happened.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

#[derive(Debug, Clone)]
struct CacheEntry {
    blocks: Vec<BlockId>,
    tokens: u64,
    /// Ref count: number of in-flight requests using this entry. Only
    /// `pins == 0` entries are eviction candidates.
    pins: u32,
    /// LRU clock value at last touch.
    last_used: u64,
    /// Engine side: the actual MM token vector. `None` in the simulator.
    payload: Option<Arc<Vec<f32>>>,
}

/// Content-addressed LRU over encoder outputs with ref-counted pinning.
///
/// All operations are O(entries) worst case on the eviction scan and O(1)
/// amortized otherwise; the cache sits off the per-token hot path (it is
/// touched once per request, not per decode step).
#[derive(Debug, Clone)]
pub struct EncoderCache {
    pool: BlockPool,
    entries: HashMap<ContentHash, CacheEntry>,
    /// Monotonic LRU clock (bumped on every touch).
    tick: u64,
    stats: EncoderCacheStats,
}

impl EncoderCache {
    /// Cache over `num_blocks` blocks of `block_tokens` tokens each.
    pub fn new(num_blocks: u32, block_tokens: u32) -> EncoderCache {
        EncoderCache {
            pool: BlockPool::new(num_blocks, block_tokens),
            entries: HashMap::new(),
            tick: 0,
            stats: EncoderCacheStats::default(),
        }
    }

    /// Cache sized to hold `capacity_tokens` MM tokens. A capacity of 0
    /// disables the cache (every lookup misses, every insert is rejected).
    pub fn with_capacity_tokens(capacity_tokens: u64, block_tokens: u32) -> EncoderCache {
        let bt = block_tokens.max(1);
        let blocks = capacity_tokens.div_ceil(bt as u64);
        EncoderCache::new(blocks.min(u32::MAX as u64) as u32, bt)
    }

    pub fn pool(&self) -> &BlockPool {
        &self.pool
    }

    pub fn stats(&self) -> EncoderCacheStats {
        self.stats
    }

    /// Cached entries (pinned + unpinned).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn contains(&self, h: ContentHash) -> bool {
        self.entries.contains_key(&h)
    }

    /// Ref count of an entry, if cached.
    pub fn pins_of(&self, h: ContentHash) -> Option<u32> {
        self.entries.get(&h).map(|e| e.pins)
    }

    pub fn tokens_of(&self, h: ContentHash) -> Option<u64> {
        self.entries.get(&h).map(|e| e.tokens)
    }

    pub fn utilization(&self) -> f64 {
        self.pool.utilization()
    }

    /// Look up `h`; on a hit, pin the entry (refcount +1), bump its LRU
    /// position and return its token count. Counts a hit or miss.
    ///
    /// Every successful `lookup_pin` must be balanced by exactly one
    /// [`Self::unpin`] once the tokens have been consumed (EP transfer
    /// confirmed / prefill job enqueued) — including when the request
    /// aborts before consuming them.
    pub fn lookup_pin(&mut self, h: ContentHash) -> Option<u64> {
        self.tick += 1;
        match self.entries.get_mut(&h) {
            Some(e) => {
                e.pins += 1;
                e.last_used = self.tick;
                self.stats.hits += 1;
                Some(e.tokens)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Shared payload of a cached entry (engine side).
    pub fn payload(&self, h: ContentHash) -> Option<Arc<Vec<f32>>> {
        self.entries.get(&h).and_then(|e| e.payload.clone())
    }

    /// Insert `tokens` MM tokens under `h`, pinned (refcount 1), evicting
    /// least-recently-used unpinned entries as needed. Returns false (and
    /// changes nothing) when even full eviction cannot make room.
    ///
    /// If `h` is already cached (two identical requests racing through the
    /// miss path), the existing entry is pinned one more time instead —
    /// the caller's balancing [`Self::unpin`] stays correct either way.
    pub fn insert_pinned(
        &mut self,
        h: ContentHash,
        tokens: u64,
        payload: Option<Arc<Vec<f32>>>,
    ) -> bool {
        self.tick += 1;
        if let Some(e) = self.entries.get_mut(&h) {
            e.pins += 1;
            e.last_used = self.tick;
            return true;
        }
        let need = self.pool.blocks_for_tokens(tokens);
        if !self.make_room(need) {
            self.stats.rejected += 1;
            return false;
        }
        let blocks = self.pool.alloc_n(need).expect("make_room guaranteed space");
        self.entries.insert(
            h,
            CacheEntry { blocks, tokens, pins: 1, last_used: self.tick, payload },
        );
        self.stats.insertions += 1;
        true
    }

    /// Release one reference to `h` (EP transfer confirmed, prefill
    /// consumed the tokens, or the request aborted). The entry stays
    /// cached; at `pins == 0` it becomes evictable.
    ///
    /// # Panics
    /// On unknown hashes or a refcount underflow — both are caller bugs
    /// (an unpin with no matching `lookup_pin`/`insert_pinned`) and must
    /// not be absorbed silently.
    pub fn unpin(&mut self, h: ContentHash) {
        let e = self
            .entries
            .get_mut(&h)
            .unwrap_or_else(|| panic!("unpin of uncached hash {h:#x}"));
        assert!(e.pins > 0, "refcount underflow for hash {h:#x}");
        e.pins -= 1;
    }

    /// Evict unpinned LRU entries until `need` blocks are free. Returns
    /// false when pinned entries make that impossible.
    fn make_room(&mut self, need: u32) -> bool {
        if need > self.pool.num_blocks() {
            return false;
        }
        while !self.pool.can_alloc(need) {
            let victim = self
                .entries
                .iter()
                .filter(|(_, e)| e.pins == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(&h, _)| h);
            match victim {
                Some(h) => {
                    let e = self.entries.remove(&h).unwrap();
                    self.pool.free_all(&e.blocks);
                    self.stats.evictions += 1;
                }
                None => return false, // everything left is pinned
            }
        }
        true
    }

    /// Drop every unpinned entry (memory-pressure reset). Pinned entries
    /// stay — they back in-flight requests.
    pub fn clear_unpinned(&mut self) {
        let victims: Vec<ContentHash> = self
            .entries
            .iter()
            .filter(|(_, e)| e.pins == 0)
            .map(|(&h, _)| h)
            .collect();
        for h in victims {
            let e = self.entries.remove(&h).unwrap();
            self.pool.free_all(&e.blocks);
            self.stats.evictions += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn miss_then_insert_then_hit() {
        let mut c = EncoderCache::new(16, 64);
        let h = content_hash(b"image-bytes");
        assert_eq!(c.lookup_pin(h), None);
        assert!(c.insert_pinned(h, 640, None)); // 10 blocks
        c.unpin(h); // transfer confirmed
        assert_eq!(c.lookup_pin(h), Some(640));
        assert_eq!(c.pins_of(h), Some(1));
        c.unpin(h);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.insertions), (1, 1, 1));
    }

    #[test]
    fn lru_eviction_prefers_oldest_unpinned() {
        let mut c = EncoderCache::new(4, 64); // room for 4 one-block entries
        for i in 0..4u64 {
            assert!(c.insert_pinned(i, 64, None));
            c.unpin(i);
        }
        // Touch entry 0 so 1 becomes the LRU victim.
        assert_eq!(c.lookup_pin(0), Some(64));
        c.unpin(0);
        assert!(c.insert_pinned(99, 64, None));
        assert!(c.contains(0), "recently used survives");
        assert!(!c.contains(1), "oldest unpinned evicted");
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn pinned_entries_never_evicted() {
        let mut c = EncoderCache::new(2, 64);
        assert!(c.insert_pinned(1, 64, None)); // stays pinned
        assert!(c.insert_pinned(2, 64, None));
        c.unpin(2);
        // Needs both blocks; only entry 2 is evictable → rejected.
        assert!(!c.insert_pinned(3, 128, None));
        assert!(c.contains(1), "pinned entry survived");
        assert_eq!(c.stats().rejected, 1);
        // After unpinning, the same insert succeeds.
        c.unpin(1);
        assert!(c.insert_pinned(3, 128, None));
        assert!(!c.contains(1));
        assert!(!c.contains(2));
    }

    #[test]
    fn refcount_release_on_abort_makes_entry_evictable() {
        let mut c = EncoderCache::new(1, 64);
        assert!(c.insert_pinned(7, 64, None));
        c.unpin(7);
        // A request pins the entry, then aborts before consuming it.
        assert_eq!(c.lookup_pin(7), Some(64));
        c.unpin(7); // abort path: release the ref without consuming
        assert_eq!(c.pins_of(7), Some(0));
        assert!(c.insert_pinned(8, 64, None), "abort left the entry evictable");
        assert!(!c.contains(7));
    }

    #[test]
    fn duplicate_insert_pins_existing_entry() {
        let mut c = EncoderCache::new(8, 64);
        assert!(c.insert_pinned(5, 128, None));
        let allocated = c.pool().allocated_blocks();
        assert!(c.insert_pinned(5, 128, None)); // racing identical miss
        assert_eq!(c.pool().allocated_blocks(), allocated, "no double alloc");
        assert_eq!(c.pins_of(5), Some(2));
        c.unpin(5);
        c.unpin(5);
        assert_eq!(c.stats().insertions, 1);
    }

    #[test]
    fn zero_capacity_disables_cleanly() {
        let mut c = EncoderCache::with_capacity_tokens(0, 64);
        assert_eq!(c.lookup_pin(1), None);
        assert!(!c.insert_pinned(1, 64, None));
        assert_eq!(c.stats().rejected, 1);
    }

    #[test]
    fn payload_roundtrip() {
        let mut c = EncoderCache::new(8, 64);
        let mm = Arc::new(vec![1.0f32, 2.0, 3.0]);
        assert!(c.insert_pinned(9, 3, Some(Arc::clone(&mm))));
        c.unpin(9);
        assert_eq!(c.lookup_pin(9), Some(3));
        assert_eq!(*c.payload(9).unwrap(), vec![1.0, 2.0, 3.0]);
        c.unpin(9);
    }

    #[test]
    fn clear_unpinned_keeps_pinned() {
        let mut c = EncoderCache::new(8, 64);
        assert!(c.insert_pinned(1, 64, None)); // pinned
        assert!(c.insert_pinned(2, 64, None));
        c.unpin(2);
        c.clear_unpinned();
        assert!(c.contains(1));
        assert!(!c.contains(2));
        assert_eq!(c.pool().allocated_blocks(), 1);
    }

    #[test]
    fn content_hash_discriminates_and_repeats() {
        assert_eq!(content_hash(b"abc"), content_hash(b"abc"));
        assert_ne!(content_hash(b"abc"), content_hash(b"abd"));
        assert_eq!(content_hash_words(&[1, 2]), content_hash_words(&[1, 2]));
        assert_ne!(content_hash_words(&[1, 2]), content_hash_words(&[2, 1]));
    }

    #[test]
    #[should_panic(expected = "refcount underflow")]
    fn unpin_underflow_panics() {
        let mut c = EncoderCache::new(4, 64);
        c.insert_pinned(1, 64, None);
        c.unpin(1);
        c.unpin(1);
    }

    #[test]
    fn conservation_under_churn() {
        let mut c = EncoderCache::new(32, 64);
        let mut rng = crate::util::rng::Rng::new(11);
        let mut pinned: Vec<ContentHash> = Vec::new();
        for i in 0..2_000u64 {
            if rng.bool(0.4) && !pinned.is_empty() {
                let k = rng.below(pinned.len() as u64) as usize;
                c.unpin(pinned.swap_remove(k));
            } else {
                let h = rng.below(64); // small key space → hits + evictions
                let tokens = 64 * (1 + rng.below(4));
                if let Some(_t) = c.lookup_pin(h) {
                    pinned.push(h);
                } else if c.insert_pinned(h, tokens, None) {
                    pinned.push(h);
                }
            }
            let pool = c.pool();
            assert_eq!(pool.free_blocks() + pool.allocated_blocks(), 32, "step {i}");
        }
        for h in pinned {
            c.unpin(h);
        }
        c.clear_unpinned();
        assert_eq!(c.pool().free_blocks(), 32, "full recovery");
    }
}
