//! Instance request queues with pluggable ordering (Appendix D: FCFS,
//! shortest-job-first, or SLO-deadline-aware).

use std::collections::VecDeque;

use crate::core::config::QueuePolicy;
use crate::core::request::{Priority, RequestId};

/// A queued unit of work: a request (or, under IRP, one shard of one) with
/// the attributes the ordering policies need.
#[derive(Debug, Clone, PartialEq)]
pub struct QueuedRequest {
    pub id: RequestId,
    /// IRP shard index (0 for whole requests).
    pub shard: u32,
    pub enqueue_time: f64,
    /// Estimated stage-processing cost, seconds (SJF key).
    pub est_cost: f64,
    /// Absolute deadline for SLO-aware ordering, seconds.
    pub deadline: f64,
    /// Priority class for class-band ordering (`QueuePolicy::Priority`).
    pub class: Priority,
}

/// A stage queue for one instance.
#[derive(Debug, Clone)]
pub struct StageQueue {
    policy: QueuePolicy,
    items: VecDeque<QueuedRequest>,
}

impl StageQueue {
    pub fn new(policy: QueuePolicy) -> StageQueue {
        StageQueue {
            policy,
            items: VecDeque::new(),
        }
    }

    pub fn policy(&self) -> QueuePolicy {
        self.policy
    }

    pub fn push(&mut self, item: QueuedRequest) {
        self.items.push_back(item);
    }

    /// Remove and return the next item according to the policy.
    pub fn pop(&mut self) -> Option<QueuedRequest> {
        if self.items.is_empty() {
            return None;
        }
        let idx = match self.policy {
            QueuePolicy::Fcfs => 0,
            QueuePolicy::Sjf => self
                .items
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.est_cost.partial_cmp(&b.1.est_cost).unwrap())
                .map(|(i, _)| i)
                .unwrap(),
            QueuePolicy::SloAware => self
                .items
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.deadline.partial_cmp(&b.1.deadline).unwrap())
                .map(|(i, _)| i)
                .unwrap(),
            QueuePolicy::Priority => self
                .items
                .iter()
                .enumerate()
                .min_by_key(|(i, q)| (q.class.band(), *i))
                .map(|(i, _)| i)
                .unwrap(),
        };
        self.items.remove(idx)
    }

    /// Peek at what `pop` would return.
    pub fn peek(&self) -> Option<&QueuedRequest> {
        match self.policy {
            QueuePolicy::Fcfs => self.items.front(),
            QueuePolicy::Sjf => self
                .items
                .iter()
                .min_by(|a, b| a.est_cost.partial_cmp(&b.est_cost).unwrap()),
            QueuePolicy::SloAware => self
                .items
                .iter()
                .min_by(|a, b| a.deadline.partial_cmp(&b.deadline).unwrap()),
            QueuePolicy::Priority => self
                .items
                .iter()
                .enumerate()
                .min_by_key(|(i, q)| (q.class.band(), *i))
                .map(|(_, q)| q),
        }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total estimated work in the queue (the role-switch monitor's load
    /// signal).
    pub fn backlog_cost(&self) -> f64 {
        self.items.iter().map(|i| i.est_cost).sum()
    }

    /// Drain everything (role-switch offload: redistribute to siblings).
    pub fn drain_all(&mut self) -> Vec<QueuedRequest> {
        self.items.drain(..).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &QueuedRequest> {
        self.items.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: RequestId, t: f64, cost: f64, deadline: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            shard: 0,
            enqueue_time: t,
            est_cost: cost,
            deadline,
            class: Priority::Interactive,
        }
    }

    #[test]
    fn fcfs_order() {
        let mut sq = StageQueue::new(QueuePolicy::Fcfs);
        sq.push(q(1, 0.0, 9.0, 100.0));
        sq.push(q(2, 1.0, 1.0, 1.0));
        assert_eq!(sq.pop().unwrap().id, 1);
        assert_eq!(sq.pop().unwrap().id, 2);
        assert!(sq.pop().is_none());
    }

    #[test]
    fn sjf_order() {
        let mut sq = StageQueue::new(QueuePolicy::Sjf);
        sq.push(q(1, 0.0, 9.0, 100.0));
        sq.push(q(2, 1.0, 1.0, 200.0));
        sq.push(q(3, 2.0, 5.0, 300.0));
        assert_eq!(sq.pop().unwrap().id, 2);
        assert_eq!(sq.pop().unwrap().id, 3);
        assert_eq!(sq.pop().unwrap().id, 1);
    }

    #[test]
    fn slo_aware_order() {
        let mut sq = StageQueue::new(QueuePolicy::SloAware);
        sq.push(q(1, 0.0, 1.0, 50.0));
        sq.push(q(2, 1.0, 1.0, 10.0));
        assert_eq!(sq.peek().unwrap().id, 2);
        assert_eq!(sq.pop().unwrap().id, 2);
    }

    #[test]
    fn priority_bands_fcfs_within() {
        let mut sq = StageQueue::new(QueuePolicy::Priority);
        let mut batch = q(1, 0.0, 1.0, 1.0);
        batch.class = Priority::Batch;
        sq.push(batch);
        sq.push(q(2, 1.0, 9.0, 9.0));
        sq.push(q(3, 2.0, 1.0, 1.0));
        // Interactive drains first (FCFS within the band), then batch.
        assert_eq!(sq.peek().unwrap().id, 2);
        assert_eq!(sq.pop().unwrap().id, 2);
        assert_eq!(sq.pop().unwrap().id, 3);
        assert_eq!(sq.pop().unwrap().id, 1);
    }

    #[test]
    fn backlog_and_drain() {
        let mut sq = StageQueue::new(QueuePolicy::Fcfs);
        sq.push(q(1, 0.0, 2.0, 0.0));
        sq.push(q(2, 0.0, 3.0, 0.0));
        assert!((sq.backlog_cost() - 5.0).abs() < 1e-12);
        let drained = sq.drain_all();
        assert_eq!(drained.len(), 2);
        assert!(sq.is_empty());
    }
}
