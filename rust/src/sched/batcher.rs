//! Batch formation: pull up to `max_batch` compatible items from a stage
//! queue, subject to an admission predicate (cache capacity, context
//! budget). Continuous batching for decode; batch-of-requests for encode
//! and prefill.

use super::queue::{QueuedRequest, StageQueue};

/// A formed batch.
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub items: Vec<QueuedRequest>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.items.len()
    }
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Batch former for one instance.
#[derive(Debug, Clone)]
pub struct Batcher {
    pub max_batch: u32,
    /// Token budget per batch (§E.1: context tokens capped at 49,152).
    pub max_batch_tokens: u64,
}

impl Batcher {
    pub fn new(max_batch: u32, max_batch_tokens: u64) -> Batcher {
        Batcher { max_batch, max_batch_tokens }
    }

    /// Form a batch by repeatedly popping the queue while (a) the batch has
    /// room, (b) the per-item `admit` predicate accepts (given tokens the
    /// item adds), and (c) the token budget holds. `tokens_of` maps an item
    /// to its token contribution. The first rejected item is pushed back.
    pub fn form<FA, FT>(&self, queue: &mut StageQueue, admit: FA, tokens_of: FT) -> Batch
    where
        FA: FnMut(&QueuedRequest) -> bool,
        FT: Fn(&QueuedRequest) -> u64,
    {
        let mut items = Vec::new();
        self.form_into(queue, admit, tokens_of, &mut items);
        Batch { items }
    }

    /// Like [`Batcher::form`], but fills a caller-supplied (recycled)
    /// vector — the simulator's hot path forms thousands of batches per
    /// second and reuses its buffers instead of allocating per batch.
    pub fn form_into<FA, FT>(
        &self,
        queue: &mut StageQueue,
        mut admit: FA,
        tokens_of: FT,
        items: &mut Vec<QueuedRequest>,
    ) where
        FA: FnMut(&QueuedRequest) -> bool,
        FT: Fn(&QueuedRequest) -> u64,
    {
        items.clear();
        let mut tokens = 0u64;
        while (items.len() as u32) < self.max_batch {
            let Some(candidate) = queue.peek() else { break };
            let t = tokens_of(candidate);
            if !items.is_empty() && tokens + t > self.max_batch_tokens {
                break;
            }
            if !admit(candidate) {
                break;
            }
            let item = queue.pop().unwrap();
            tokens += t;
            items.push(item);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::config::QueuePolicy;
    use crate::core::request::Priority;

    fn q(id: u64, cost: f64) -> QueuedRequest {
        QueuedRequest {
            id,
            shard: 0,
            enqueue_time: 0.0,
            est_cost: cost,
            deadline: f64::INFINITY,
            class: Priority::Interactive,
        }
    }

    fn queue_with(n: u64) -> StageQueue {
        let mut sq = StageQueue::new(QueuePolicy::Fcfs);
        for i in 0..n {
            sq.push(q(i, 1.0));
        }
        sq
    }

    #[test]
    fn respects_max_batch() {
        let mut sq = queue_with(10);
        let b = Batcher::new(4, u64::MAX).form(&mut sq, |_| true, |_| 1);
        assert_eq!(b.len(), 4);
        assert_eq!(sq.len(), 6);
    }

    #[test]
    fn respects_token_budget() {
        let mut sq = queue_with(10);
        let b = Batcher::new(100, 25).form(&mut sq, |_| true, |_| 10);
        // 10 + 10 fits; adding a third (30 > 25) does not.
        assert_eq!(b.len(), 2);
    }

    #[test]
    fn first_item_always_admitted_past_token_budget() {
        // A single huge request must still be schedulable (chunked prefill
        // is out of scope; the budget only limits *batching*).
        let mut sq = queue_with(2);
        let b = Batcher::new(4, 5).form(&mut sq, |_| true, |_| 100);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn admission_predicate_stops_batch() {
        let mut sq = queue_with(5);
        let mut admitted = 0;
        let b = Batcher::new(10, u64::MAX).form(
            &mut sq,
            |_| {
                admitted += 1;
                admitted <= 3
            },
            |_| 1,
        );
        assert_eq!(b.len(), 3);
        assert_eq!(sq.len(), 2, "rejected item stays queued");
    }

    #[test]
    fn empty_queue_empty_batch() {
        let mut sq = queue_with(0);
        let b = Batcher::new(4, 100).form(&mut sq, |_| true, |_| 1);
        assert!(b.is_empty());
    }
}
