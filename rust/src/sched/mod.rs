//! Per-stage scheduling: queue ordering policies, instance assignment and
//! batch formation (Appendix D).

pub mod queue;
pub mod assign;
pub mod batcher;

pub use assign::Assigner;
pub use batcher::{Batch, Batcher};
pub use queue::{QueuedRequest, StageQueue};
