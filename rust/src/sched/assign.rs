//! Instance assignment at stage entry (Appendix D): round-robin or
//! least-loaded-first over the instances currently serving a stage, plus
//! content-affinity assignment (rendezvous hashing) for the cross-request
//! encoder cache — repeated media keeps landing on the same encode
//! instance so its warm state is actually reused.

use crate::core::config::AssignPolicy;

/// Stateful assigner over a dynamic set of instances (identified by dense
/// indices supplied per call — the set changes under role switching).
#[derive(Debug, Clone)]
pub struct Assigner {
    policy: AssignPolicy,
    rr_cursor: usize,
}

impl Assigner {
    pub fn new(policy: AssignPolicy) -> Assigner {
        Assigner { policy, rr_cursor: 0 }
    }

    pub fn policy(&self) -> AssignPolicy {
        self.policy
    }

    /// Choose one of `candidates` (instance ids) given their current load
    /// (`loads[i]` corresponds to `candidates[i]`; lower is better).
    /// Returns `None` when no candidate exists.
    pub fn pick(&mut self, candidates: &[usize], loads: &[f64]) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        debug_assert_eq!(candidates.len(), loads.len());
        match self.policy {
            AssignPolicy::RoundRobin => {
                let i = self.rr_cursor % candidates.len();
                self.rr_cursor = self.rr_cursor.wrapping_add(1);
                Some(candidates[i])
            }
            AssignPolicy::LeastLoaded => {
                let mut best = 0usize;
                for i in 1..candidates.len() {
                    if loads[i] < loads[best] {
                        best = i;
                    }
                }
                Some(candidates[best])
            }
        }
    }

    /// Content-affinity pick: rendezvous (highest-random-weight) hashing
    /// of `key` over `candidates`, so the same media hash deterministically
    /// routes to the same instance while distinct hashes spread uniformly
    /// — the assignment that makes per-instance encoder-cache state pay
    /// off and that survives the candidate set growing or shrinking under
    /// role switching (only ~1/n of keys move).
    ///
    /// Overload guard: when the affinity winner's load exceeds the current
    /// minimum by more than `2× min + 1`, affinity yields to the policy
    /// pick — a hot key must not melt one instance while siblings idle.
    pub fn pick_affinity(
        &mut self,
        candidates: &[usize],
        loads: &[f64],
        key: u64,
    ) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        debug_assert_eq!(candidates.len(), loads.len());
        let mut best = 0usize;
        let mut best_w = 0u64;
        for (i, &c) in candidates.iter().enumerate() {
            let w = mix64(key ^ (c as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            if i == 0 || w > best_w {
                best = i;
                best_w = w;
            }
        }
        let min_load = loads.iter().copied().fold(f64::INFINITY, f64::min);
        if loads[best] > 2.0 * min_load + 1.0 {
            return self.pick(candidates, loads);
        }
        Some(candidates[best])
    }
}

/// SplitMix64 finalizer — a cheap, well-distributed 64-bit mixer.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut a = Assigner::new(AssignPolicy::RoundRobin);
        let c = [10, 20, 30];
        let l = [0.0; 3];
        let picks: Vec<usize> = (0..6).map(|_| a.pick(&c, &l).unwrap()).collect();
        assert_eq!(picks, vec![10, 20, 30, 10, 20, 30]);
    }

    #[test]
    fn least_loaded_picks_minimum() {
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        let c = [10, 20, 30];
        assert_eq!(a.pick(&c, &[3.0, 1.0, 2.0]), Some(20));
        assert_eq!(a.pick(&c, &[0.5, 1.0, 2.0]), Some(10));
    }

    #[test]
    fn least_loaded_ties_prefer_first() {
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        assert_eq!(a.pick(&[7, 8], &[1.0, 1.0]), Some(7));
    }

    #[test]
    fn empty_candidates() {
        let mut a = Assigner::new(AssignPolicy::RoundRobin);
        assert_eq!(a.pick(&[], &[]), None);
    }

    #[test]
    fn affinity_is_sticky_per_key() {
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        let c = [10, 20, 30];
        let l = [0.0; 3];
        for key in [1u64, 42, 0xDEAD_BEEF] {
            let first = a.pick_affinity(&c, &l, key).unwrap();
            for _ in 0..5 {
                assert_eq!(a.pick_affinity(&c, &l, key), Some(first));
            }
        }
    }

    #[test]
    fn affinity_spreads_distinct_keys() {
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        let c = [0, 1, 2, 3];
        let l = [0.0; 4];
        let mut counts = [0u32; 4];
        for key in 0..4000u64 {
            counts[a.pick_affinity(&c, &l, key).unwrap()] += 1;
        }
        for (i, &n) in counts.iter().enumerate() {
            assert!((700..=1300).contains(&n), "instance {i} got {n} of 4000");
        }
    }

    #[test]
    fn affinity_mostly_stable_under_membership_change() {
        // Rendezvous property: removing one of four instances moves only
        // the keys that lived there (~25%), not a full reshuffle.
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        let all = [0usize, 1, 2, 3];
        let fewer = [0usize, 1, 2];
        let l4 = [0.0; 4];
        let l3 = [0.0; 3];
        let mut moved = 0;
        for key in 0..1000u64 {
            let before = a.pick_affinity(&all, &l4, key).unwrap();
            let after = a.pick_affinity(&fewer, &l3, key).unwrap();
            if before != 3 && before != after {
                moved += 1;
            }
        }
        assert_eq!(moved, 0, "surviving instances keep their keys");
    }

    #[test]
    fn affinity_yields_to_load_when_winner_overloaded() {
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        let c = [10, 20];
        // Find a key whose affinity winner is index 0, then overload it.
        let key = (0..64u64)
            .find(|&k| a.pick_affinity(&c, &[0.0, 0.0], k) == Some(10))
            .unwrap();
        assert_eq!(a.pick_affinity(&c, &[100.0, 0.1], key), Some(20));
    }

    #[test]
    fn round_robin_survives_shrinking_set() {
        let mut a = Assigner::new(AssignPolicy::RoundRobin);
        let l3 = [0.0; 3];
        let l1 = [0.0; 1];
        a.pick(&[1, 2, 3], &l3);
        a.pick(&[1, 2, 3], &l3);
        // Set shrinks (role switch took an instance away) — must not panic.
        assert!(a.pick(&[9], &l1).is_some());
    }
}
