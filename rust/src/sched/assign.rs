//! Instance assignment at stage entry (Appendix D): round-robin or
//! least-loaded-first over the instances currently serving a stage.

use crate::core::config::AssignPolicy;

/// Stateful assigner over a dynamic set of instances (identified by dense
/// indices supplied per call — the set changes under role switching).
#[derive(Debug, Clone)]
pub struct Assigner {
    policy: AssignPolicy,
    rr_cursor: usize,
}

impl Assigner {
    pub fn new(policy: AssignPolicy) -> Assigner {
        Assigner { policy, rr_cursor: 0 }
    }

    pub fn policy(&self) -> AssignPolicy {
        self.policy
    }

    /// Choose one of `candidates` (instance ids) given their current load
    /// (`loads[i]` corresponds to `candidates[i]`; lower is better).
    /// Returns `None` when no candidate exists.
    pub fn pick(&mut self, candidates: &[usize], loads: &[f64]) -> Option<usize> {
        if candidates.is_empty() {
            return None;
        }
        debug_assert_eq!(candidates.len(), loads.len());
        match self.policy {
            AssignPolicy::RoundRobin => {
                let i = self.rr_cursor % candidates.len();
                self.rr_cursor = self.rr_cursor.wrapping_add(1);
                Some(candidates[i])
            }
            AssignPolicy::LeastLoaded => {
                let mut best = 0usize;
                for i in 1..candidates.len() {
                    if loads[i] < loads[best] {
                        best = i;
                    }
                }
                Some(candidates[best])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut a = Assigner::new(AssignPolicy::RoundRobin);
        let c = [10, 20, 30];
        let l = [0.0; 3];
        let picks: Vec<usize> = (0..6).map(|_| a.pick(&c, &l).unwrap()).collect();
        assert_eq!(picks, vec![10, 20, 30, 10, 20, 30]);
    }

    #[test]
    fn least_loaded_picks_minimum() {
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        let c = [10, 20, 30];
        assert_eq!(a.pick(&c, &[3.0, 1.0, 2.0]), Some(20));
        assert_eq!(a.pick(&c, &[0.5, 1.0, 2.0]), Some(10));
    }

    #[test]
    fn least_loaded_ties_prefer_first() {
        let mut a = Assigner::new(AssignPolicy::LeastLoaded);
        assert_eq!(a.pick(&[7, 8], &[1.0, 1.0]), Some(7));
    }

    #[test]
    fn empty_candidates() {
        let mut a = Assigner::new(AssignPolicy::RoundRobin);
        assert_eq!(a.pick(&[], &[]), None);
    }

    #[test]
    fn round_robin_survives_shrinking_set() {
        let mut a = Assigner::new(AssignPolicy::RoundRobin);
        let l3 = [0.0; 3];
        let l1 = [0.0; 1];
        a.pick(&[1, 2, 3], &l3);
        a.pick(&[1, 2, 3], &l3);
        // Set shrinks (role switch took an instance away) — must not panic.
        assert!(a.pick(&[9], &l1).is_some());
    }
}
