//! Deterministic fault injection for the simulator: seeded chaos plans
//! replayed bit-for-bit against the discrete-event engine.
//!
//! A [`FaultPlan`] is a *schedule*, fixed before the run starts: instance
//! crashes (fail-stop with a restart after `downtime`), link
//! degradation/flapping windows fed into
//! [`LinkScheduler`](crate::sim::link::LinkScheduler), per-instance
//! straggler multipliers applied through [`StragglerMap`]
//! (see [`cost`](crate::sim::cost)), and encoder OOMs that abort the
//! in-flight shard batch. The engine executes the plan through the same
//! seams role switching already uses (`begin_switch` / `pd_retarget`):
//! a crashed instance drains, its queued work re-homes to same-kind
//! siblings, streamed-PD reservations on the dead target are released and
//! re-reserved, and parked requests wake when the instance restarts.
//!
//! Everything defaults off: [`FaultPlan::none()`] schedules nothing, adds
//! no events, and leaves every simulated quantity bit-for-bit identical
//! to a run without the fault layer. With a non-empty plan, the same seed
//! and the same plan replay byte-identically (`SimOutcome::to_json()`),
//! so chaos scenarios are regression-testable rather than flaky.

use std::ops::{Deref, DerefMut};

use crate::metrics::resilience::ResilienceCounters;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A scheduled fail-stop crash: the instance loses all queued work,
/// active decode state and reservations at `at`, drains through the
/// switch seam, and restarts in the same role after `downtime`.
#[derive(Debug, Clone, PartialEq)]
pub struct CrashFault {
    /// Virtual time of the crash (seconds).
    pub at: f64,
    /// Instance index into `EpdConfig::instances`.
    pub instance: usize,
    /// Seconds until the instance restarts (same role, cold caches).
    pub downtime: f64,
}

/// A link-degradation window: transfers touching `instance` take
/// `factor`× as long during `[at, at + duration)`. Scheduling two
/// overlapping windows on the same instance is a flap; the last event to
/// fire wins.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkFault {
    pub at: f64,
    pub instance: usize,
    /// Service-time multiplier while degraded (>= 1 slows the link).
    pub factor: f64,
    /// Window length in seconds; the link restores to 1.0 at the end.
    pub duration: f64,
}

/// A permanent per-instance straggler: every stage duration on
/// `instance` is multiplied by `factor` for the whole run.
#[derive(Debug, Clone, PartialEq)]
pub struct StragglerFault {
    pub instance: usize,
    /// Service-time multiplier (>= 1 slows the instance).
    pub factor: f64,
}

/// An encoder OOM: if `instance` is an encode-kind instance with an
/// in-flight shard batch at `at`, the batch aborts and its shards re-run
/// after the failed step's window (chunked EP emission is already on the
/// wire and is not recalled; see ARCHITECTURE.md).
#[derive(Debug, Clone, PartialEq)]
pub struct OomFault {
    pub at: f64,
    pub instance: usize,
}

/// A deterministic chaos schedule. The default ([`FaultPlan::none()`])
/// is empty and bit-for-bit dormant.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub crashes: Vec<CrashFault>,
    pub links: Vec<LinkFault>,
    pub stragglers: Vec<StragglerFault>,
    pub ooms: Vec<OomFault>,
    /// Window length (seconds) for the post-fault SLO recovery metrics in
    /// [`ResilienceStats`]. Only read when the plan schedules timed
    /// faults.
    pub slo_window: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan::none()
    }
}

impl FaultPlan {
    /// The empty plan: injects nothing, bit-for-bit identical behavior.
    pub fn none() -> FaultPlan {
        FaultPlan {
            crashes: Vec::new(),
            links: Vec::new(),
            stragglers: Vec::new(),
            ooms: Vec::new(),
            slo_window: 2.0,
        }
    }

    /// True when the plan schedules nothing at all.
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.links.is_empty()
            && self.stragglers.is_empty()
            && self.ooms.is_empty()
    }

    /// Builder: schedule a crash.
    pub fn with_crash(mut self, at: f64, instance: usize, downtime: f64) -> FaultPlan {
        assert!(at.is_finite() && downtime > 0.0, "crash needs finite at and downtime > 0");
        self.crashes.push(CrashFault { at, instance, downtime });
        self
    }

    /// Builder: schedule a link-degradation window.
    pub fn with_link_degrade(
        mut self,
        at: f64,
        instance: usize,
        factor: f64,
        duration: f64,
    ) -> FaultPlan {
        assert!(at.is_finite() && factor > 0.0 && duration > 0.0);
        self.links.push(LinkFault { at, instance, factor, duration });
        self
    }

    /// Builder: a permanent straggler.
    pub fn with_straggler(mut self, instance: usize, factor: f64) -> FaultPlan {
        assert!(factor > 0.0, "straggler factor must be positive");
        self.stragglers.push(StragglerFault { instance, factor });
        self
    }

    /// Builder: schedule an encoder OOM.
    pub fn with_encoder_oom(mut self, at: f64, instance: usize) -> FaultPlan {
        assert!(at.is_finite());
        self.ooms.push(OomFault { at, instance });
        self
    }

    /// A seeded fault wave against an `n_instances` cluster: around time
    /// `at`, crash `crashes` distinct instances for `downtime` seconds
    /// each (staggered), degrade ~a quarter of the links by `link_factor`
    /// for the wave, slow ~an eighth of the instances by
    /// `straggler_factor` for the whole run, and inject one encoder OOM.
    /// Pure function of its arguments: same inputs, same plan.
    pub fn wave(
        seed: u64,
        n_instances: usize,
        at: f64,
        crashes: usize,
        downtime: f64,
        link_factor: f64,
        straggler_factor: f64,
    ) -> FaultPlan {
        let mut plan = FaultPlan::none();
        if n_instances == 0 {
            return plan;
        }
        let mut rng = Rng::new(seed ^ 0xFA17_0000_0000_0001);
        let mut order: Vec<usize> = (0..n_instances).collect();
        rng.shuffle(&mut order);
        let crashes = crashes.min(n_instances);
        for (k, &inst) in order.iter().take(crashes).enumerate() {
            let jitter = rng.uniform(0.0, 0.25 * downtime.max(1e-9));
            plan = plan.with_crash(at + k as f64 * 0.5 + jitter, inst, downtime.max(1e-3));
        }
        if link_factor > 1.0 {
            let n_links = n_instances.div_ceil(4);
            for &inst in order.iter().rev().take(n_links) {
                plan = plan.with_link_degrade(at, inst, link_factor, downtime.max(1e-3));
            }
        }
        if straggler_factor > 1.0 {
            let n_slow = n_instances.div_ceil(8);
            for &inst in order.iter().skip(crashes).take(n_slow) {
                plan = plan.with_straggler(inst, straggler_factor);
            }
        }
        plan = plan.with_encoder_oom(at, order[rng.below(n_instances as u64) as usize]);
        plan
    }

    /// Build the plan the `fault_*` config keys describe: empty when
    /// `fault_seed == 0` (the default — chaos stays off and dormant),
    /// otherwise a seeded [`FaultPlan::wave`] against the config's own
    /// instance count.
    pub fn from_epd(epd: &crate::core::config::EpdConfig) -> FaultPlan {
        if epd.fault_seed == 0 {
            return FaultPlan::none();
        }
        FaultPlan::wave(
            epd.fault_seed,
            epd.instances.len(),
            epd.fault_wave_at,
            epd.fault_crashes as usize,
            epd.fault_downtime,
            epd.fault_link_factor,
            epd.fault_straggler_factor,
        )
    }

    /// Drop every entry that names an instance outside `0..n`; keeps the
    /// plan well-formed against an arbitrary topology.
    pub fn clamp_instances(&mut self, n: usize) {
        self.crashes.retain(|c| c.instance < n);
        self.links.retain(|l| l.instance < n);
        self.stragglers.retain(|s| s.instance < n);
        self.ooms.retain(|o| o.instance < n);
    }

    /// Flatten the plan into a time-sorted action schedule for the
    /// engine. Stragglers are static (applied at construction) and do
    /// not appear; each link window contributes a degrade and a restore
    /// action. Ties break by insertion order (stable sort), so the
    /// schedule — and therefore the replay — is deterministic.
    pub fn schedule(&self) -> Vec<FaultAction> {
        let mut out = Vec::new();
        for c in &self.crashes {
            out.push(FaultAction {
                at: c.at,
                instance: c.instance,
                kind: FaultKind::Crash { downtime: c.downtime },
            });
        }
        for l in &self.links {
            out.push(FaultAction {
                at: l.at,
                instance: l.instance,
                kind: FaultKind::LinkDegrade { factor: l.factor },
            });
            out.push(FaultAction {
                at: l.at + l.duration,
                instance: l.instance,
                kind: FaultKind::LinkRestore,
            });
        }
        for o in &self.ooms {
            out.push(FaultAction { at: o.at, instance: o.instance, kind: FaultKind::EncoderOom });
        }
        out.sort_by(|a, b| a.at.partial_cmp(&b.at).expect("finite fault times"));
        out
    }

    /// Earliest timed fault, or +inf for plans with only stragglers (or
    /// nothing): the anchor for the recovery-time metrics.
    pub fn first_fault_at(&self) -> f64 {
        let mut t = f64::INFINITY;
        for c in &self.crashes {
            t = t.min(c.at);
        }
        for l in &self.links {
            t = t.min(l.at);
        }
        for o in &self.ooms {
            t = t.min(o.at);
        }
        t
    }
}

/// One executable step of a flattened [`FaultPlan`].
#[derive(Debug, Clone, PartialEq)]
pub struct FaultAction {
    pub at: f64,
    pub instance: usize,
    pub kind: FaultKind,
}

/// What a [`FaultAction`] does when it fires.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultKind {
    /// Fail-stop; the instance restarts in the same role after `downtime`.
    Crash { downtime: f64 },
    /// Multiply transfer times touching the instance by `factor`.
    LinkDegrade { factor: f64 },
    /// Restore the instance's link factor to 1.0.
    LinkRestore,
    /// Abort the in-flight encode shard batch, if any.
    EncoderOom,
}

/// Resilience accounting attached to
/// [`SimOutcome`](crate::sim::outcome::SimOutcome) — all zeros when the
/// plan is empty and the health layer is off.
///
/// The counters shared with the engine recorder (crashes, lost/retried/
/// re-targeted requests, breaker/hedge/retry-budget events) live in the
/// embedded [`ResilienceCounters`]; this struct `Deref`s to it so
/// `stats.crashes`-style access keeps working, and appends the
/// sim-only chaos event counts and recovery metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResilienceStats {
    /// The schema shared with `metrics/recorder.rs` — see
    /// [`crate::metrics::resilience`].
    pub counters: ResilienceCounters,
    /// Link-degradation windows that began.
    pub link_degradations: u64,
    /// Encoder OOMs that actually aborted an in-flight batch.
    pub encoder_ooms: u64,
    /// Instances running with a straggler multiplier != 1.
    pub straggler_instances: u64,
    /// Seconds from the first timed fault until windowed SLO attainment
    /// is back at its pre-fault level (0 when never degraded; capped at
    /// the end of the run when it never recovers).
    pub recovery_seconds: f64,
    /// Worst post-fault drop in windowed SLO attainment relative to the
    /// pre-fault level, in [0, 1].
    pub slo_dip: f64,
}

impl Deref for ResilienceStats {
    type Target = ResilienceCounters;
    fn deref(&self) -> &ResilienceCounters {
        &self.counters
    }
}

impl DerefMut for ResilienceStats {
    fn deref_mut(&mut self) -> &mut ResilienceCounters {
        &mut self.counters
    }
}

impl ResilienceStats {
    pub fn to_json(&self) -> Json {
        let mut fields = self.counters.json_fields();
        fields.push(("link_degradations", Json::num(self.link_degradations as f64)));
        fields.push(("encoder_ooms", Json::num(self.encoder_ooms as f64)));
        fields.push(("straggler_instances", Json::num(self.straggler_instances as f64)));
        fields.push(("recovery_seconds", Json::num(self.recovery_seconds)));
        fields.push(("slo_dip", Json::num(self.slo_dip)));
        Json::obj(fields)
    }
}

/// Post-fault SLO recovery metrics from windowed attainment counters.
///
/// `windows[i]` counts `(terminated, slo_attained)` requests in
/// `[i*window, (i+1)*window)`. The pre-fault level is attainment over the
/// windows that end before `first_fault_at`; the dip is the worst
/// shortfall of any non-empty post-fault window below that level; the
/// recovery time is the gap from `first_fault_at` to the start of the
/// first non-empty post-fault window back at the pre-fault level (capped
/// at `makespan - first_fault_at` when it never recovers).
pub fn recovery_metrics(
    windows: &[(u64, u64)],
    window: f64,
    first_fault_at: f64,
    makespan: f64,
) -> (f64, f64) {
    if windows.is_empty() || !first_fault_at.is_finite() || window <= 0.0 {
        return (0.0, 0.0);
    }
    let first_idx = (first_fault_at / window) as usize;
    let (mut pre_fin, mut pre_att) = (0u64, 0u64);
    for &(fin, att) in windows.iter().take(first_idx) {
        pre_fin += fin;
        pre_att += att;
    }
    let pre = if pre_fin > 0 { pre_att as f64 / pre_fin as f64 } else { 1.0 };
    let mut dip = 0.0f64;
    let mut recovery = None;
    for (i, &(fin, att)) in windows.iter().enumerate().skip(first_idx) {
        if fin == 0 {
            continue;
        }
        let a = att as f64 / fin as f64;
        dip = dip.max(pre - a);
        if recovery.is_none() && a >= pre {
            recovery = Some(((i as f64) * window - first_fault_at).max(0.0));
        }
    }
    let recovery = recovery.unwrap_or_else(|| (makespan - first_fault_at).max(0.0));
    (recovery, dip.clamp(0.0, 1.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_is_empty_and_schedules_nothing() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert!(p.schedule().is_empty());
        assert_eq!(p.first_fault_at(), f64::INFINITY);
        assert_eq!(p, FaultPlan::default());
    }

    #[test]
    fn builders_populate_and_flatten_sorted() {
        let p = FaultPlan::none()
            .with_crash(5.0, 1, 2.0)
            .with_link_degrade(1.0, 0, 4.0, 3.0)
            .with_straggler(2, 1.5)
            .with_encoder_oom(2.0, 0);
        assert!(!p.is_empty());
        let s = p.schedule();
        // crash@5, degrade@1, restore@4, oom@2 -> sorted by time.
        assert_eq!(s.len(), 4);
        assert_eq!(s[0].kind, FaultKind::LinkDegrade { factor: 4.0 });
        assert_eq!(s[1].kind, FaultKind::EncoderOom);
        assert_eq!(s[2].kind, FaultKind::LinkRestore);
        assert_eq!(s[3].kind, FaultKind::Crash { downtime: 2.0 });
        for w in s.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
        assert_eq!(p.first_fault_at(), 1.0);
    }

    #[test]
    fn wave_is_deterministic_and_in_range() {
        let a = FaultPlan::wave(9, 8, 10.0, 2, 5.0, 4.0, 1.5);
        let b = FaultPlan::wave(9, 8, 10.0, 2, 5.0, 4.0, 1.5);
        assert_eq!(a, b, "same seed, same plan");
        assert_eq!(a.crashes.len(), 2);
        assert!(!a.links.is_empty() && !a.stragglers.is_empty());
        for c in &a.crashes {
            assert!(c.instance < 8 && c.at >= 10.0);
        }
        let c = FaultPlan::wave(10, 8, 10.0, 2, 5.0, 4.0, 1.5);
        assert_ne!(a, c, "different seed, different plan");
        // Distinct crash targets.
        assert_ne!(a.crashes[0].instance, a.crashes[1].instance);
    }

    #[test]
    fn from_epd_is_off_by_default_and_seeded_on() {
        use crate::core::config::EpdConfig;
        use crate::core::topology::Topology;
        let epd = EpdConfig::epd(Topology::new(2, 1, 1), 1, 1, 128);
        assert!(FaultPlan::from_epd(&epd).is_empty(), "seed 0 = chaos off");
        let mut on = epd.clone();
        on.fault_seed = 42;
        on.fault_crashes = 2;
        let p = FaultPlan::from_epd(&on);
        assert_eq!(p.crashes.len(), 2);
        assert_eq!(p, FaultPlan::from_epd(&on), "same config, same plan");
    }

    #[test]
    fn clamp_drops_out_of_range_instances() {
        let mut p = FaultPlan::none()
            .with_crash(1.0, 9, 1.0)
            .with_crash(1.0, 0, 1.0)
            .with_link_degrade(1.0, 9, 2.0, 1.0)
            .with_straggler(9, 2.0)
            .with_encoder_oom(1.0, 9);
        p.clamp_instances(2);
        assert_eq!(p.crashes.len(), 1);
        assert!(p.links.is_empty() && p.stragglers.is_empty() && p.ooms.is_empty());
    }

    #[test]
    fn recovery_metrics_shapes() {
        // No windows / no fault: zeros.
        assert_eq!(recovery_metrics(&[], 2.0, 1.0, 10.0), (0.0, 0.0));
        assert_eq!(recovery_metrics(&[(4, 4)], 2.0, f64::INFINITY, 10.0), (0.0, 0.0));
        // Pre-fault 100%, one bad window, then recovered.
        // windows: [0,2) full, [2,4) half, [4,6) full; fault at 2.0.
        let w = [(10, 10), (10, 5), (10, 10)];
        let (rec, dip) = recovery_metrics(&w, 2.0, 2.0, 6.0);
        assert!((dip - 0.5).abs() < 1e-12, "dip {dip}");
        assert!((rec - 2.0).abs() < 1e-12, "recovered at window 2 start (t=4): {rec}");
        // Never recovers: capped at makespan - fault time.
        let w = [(10, 10), (10, 5), (10, 6)];
        let (rec, _) = recovery_metrics(&w, 2.0, 2.0, 9.0);
        assert!((rec - 7.0).abs() < 1e-12, "rec {rec}");
    }

    #[test]
    fn resilience_json_has_all_fields() {
        let mut s = ResilienceStats {
            counters: ResilienceCounters { crashes: 2, requests_lost: 1, ..Default::default() },
            ..Default::default()
        };
        s.quarantines += 3; // through DerefMut into the shared counters
        assert_eq!(s.crashes, 2, "Deref reads the shared counters");
        let j = s.to_json();
        assert_eq!(j.get("crashes").unwrap().as_f64(), Some(2.0));
        assert_eq!(j.get("requests_lost").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("quarantines").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("hedges_issued").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("retry_budget_exhausted").unwrap().as_f64(), Some(0.0));
        assert_eq!(j.get("slo_dip").unwrap().as_f64(), Some(0.0));
    }
}
