//! Per-instance link scheduling for inter-stage transfers.
//!
//! Every instance owns one full-duplex NIC modelled as two independent
//! channels: an **egress** channel for outbound payloads and an
//! **ingress** channel for inbound ones. A transfer from instance `src`
//! to instance `dst` occupies `src`'s egress and `dst`'s ingress for
//! `bytes / bandwidth` seconds and is delivered one latency floor after
//! its last byte leaves the wire.
//!
//! Two modes, selected by [`EpdConfig::link_contention`]:
//!
//! - **Free overlap** (default, the repo's historical model): every
//!   transfer starts the instant it is ready, regardless of what else is
//!   on the link. Arrival times are *bit-for-bit identical* to calling
//!   [`TransferModel::migration_time`] directly, so flipping the flag off
//!   reproduces old runs exactly; the scheduler still accounts per-link
//!   busy time (with zero queueing).
//! - **Contended**: each channel keeps a calendar of reserved busy
//!   intervals, and a transfer claims the earliest slot at or after its
//!   ready time that is free on *both* endpoint channels. Because the
//!   calendar fills gaps, a transfer that becomes ready early is never
//!   blocked by a reservation parked further in the future (layer-wise
//!   PD streaming reserves whole passes ahead of time); it only waits for
//!   bytes that genuinely occupy the wire when it wants it, and that wait
//!   lands in [`LinkStats::queue_seconds`]. This is the fidelity fix that
//!   keeps layer-wise PD streaming honest — the overlapped group
//!   transfers must pay for the links they share with EP traffic and
//!   with each other.
//!
//! Endpoints are optional because not every transfer has a modelled NIC
//! on both sides: the EP edge resolves its destination instance only at
//! prefill admission (so EP transfers contend on the encoder's egress
//! alone), and encoder-cache hits serve chunks from the cache holder
//! rather than a live encode instance.
//!
//! [`EpdConfig::link_contention`]: crate::core::config::EpdConfig::link_contention
//! [`TransferModel::migration_time`]: crate::coordinator::migration::TransferModel::migration_time

use crate::coordinator::migration::TransferModel;

/// Per-link (per-instance NIC) transfer counters, reported in
/// [`SimOutcome::links`](crate::sim::SimOutcome::links). A transfer is
/// counted at every modelled endpoint, so one `src → dst` move shows up
/// on both instances' rows.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LinkStats {
    /// Seconds the egress channel spent moving bytes out.
    pub egress_busy_seconds: f64,
    /// Seconds the ingress channel spent moving bytes in.
    pub ingress_busy_seconds: f64,
    /// Seconds transfers waited for this link's channels to free up
    /// (always zero under free overlap). Attributed to the source
    /// endpoint when one is modelled, else to the destination.
    pub queue_seconds: f64,
    /// Transfers that touched this link (as source or destination).
    pub transfers: u64,
}

/// One channel's calendar: non-overlapping reserved `[start, end)`
/// intervals, sorted by start (and therefore by end).
#[derive(Debug, Clone, Default)]
struct Channel {
    busy: Vec<(f64, f64)>,
}

impl Channel {
    /// End of the first reserved interval overlapping `[s, e)`, if any.
    fn conflict(&self, s: f64, e: f64) -> Option<f64> {
        let i = self.busy.partition_point(|iv| iv.1 <= s);
        match self.busy.get(i) {
            Some(&(bs, be)) if bs < e => Some(be),
            _ => None,
        }
    }

    fn reserve(&mut self, s: f64, e: f64) {
        let i = self.busy.partition_point(|iv| iv.0 < s);
        self.busy.insert(i, (s, e));
    }

    /// Drop reservations that ended at or before `now`: every future
    /// transfer is scheduled with `ready >= now` (simulation time only
    /// moves forward), so they can never conflict again. Keeps the
    /// calendar bounded by the in-flight window instead of the whole run.
    fn prune(&mut self, now: f64) {
        let k = self.busy.partition_point(|iv| iv.1 <= now);
        if k > 0 {
            self.busy.drain(..k);
        }
    }
}

/// Serializes transfers over the per-instance links (see module docs).
#[derive(Debug, Clone)]
pub struct LinkScheduler {
    contended: bool,
    egress: Vec<Channel>,
    ingress: Vec<Channel>,
    stats: Vec<LinkStats>,
    /// Fault-injection seam: per-instance service-time multiplier
    /// (1.0 = healthy). A transfer takes `max(degrade[src],
    /// degrade[dst])` times as long on the wire. All-ones is the exact
    /// identity: the `slow == 1.0` path reproduces the historical
    /// arithmetic bit for bit.
    degrade: Vec<f64>,
}

impl LinkScheduler {
    pub fn new(num_links: usize, contended: bool) -> LinkScheduler {
        LinkScheduler {
            contended,
            egress: vec![Channel::default(); num_links],
            ingress: vec![Channel::default(); num_links],
            stats: vec![LinkStats::default(); num_links],
            degrade: vec![1.0; num_links],
        }
    }

    pub fn contended(&self) -> bool {
        self.contended
    }

    /// Degrade (factor > 1) or restore (factor = 1) `instance`'s link.
    /// Applies to transfers scheduled from now on; in-flight transfers
    /// keep their original delivery times (the bytes already left).
    pub fn set_degradation(&mut self, instance: usize, factor: f64) {
        if instance < self.degrade.len() {
            self.degrade[instance] = factor.max(1e-9);
        }
    }

    /// Current degradation factor for `instance` (1.0 = healthy).
    pub fn degradation(&self, instance: usize) -> f64 {
        self.degrade.get(instance).copied().unwrap_or(1.0)
    }

    /// Schedule a transfer of `bytes` that becomes ready at `ready`
    /// (`ready >= now`, the caller's current simulation time — `now`
    /// anchors calendar pruning), from `src`'s egress to `dst`'s ingress
    /// (either endpoint may be unmodelled). Returns the delivery time at
    /// the destination: `start + latency + bytes/bandwidth`, where
    /// `start == ready` under free overlap and is the earliest instant
    /// with `bytes/bandwidth` of simultaneous free time on both channels
    /// under contention.
    pub fn schedule(
        &mut self,
        tm: &TransferModel,
        now: f64,
        ready: f64,
        src: Option<usize>,
        dst: Option<usize>,
        bytes: u64,
    ) -> f64 {
        debug_assert!(ready >= now, "transfers cannot be ready in the past");
        let slow = {
            let a = src.map_or(1.0, |i| self.degrade[i]);
            let b = dst.map_or(1.0, |i| self.degrade[i]);
            a.max(b)
        };
        let duration = if slow == 1.0 {
            bytes as f64 / tm.bandwidth
        } else {
            bytes as f64 * slow / tm.bandwidth
        };
        let mut start = ready;
        if self.contended && duration > 0.0 {
            if let Some(i) = src {
                self.egress[i].prune(now);
            }
            if let Some(i) = dst {
                self.ingress[i].prune(now);
            }
            // First-fit over both calendars: bump past whichever
            // reservation overlaps the candidate window until none does.
            loop {
                let c_src = src.and_then(|i| self.egress[i].conflict(start, start + duration));
                let c_dst = dst.and_then(|i| self.ingress[i].conflict(start, start + duration));
                match (c_src, c_dst) {
                    (None, None) => break,
                    (a, b) => start = a.unwrap_or(f64::MIN).max(b.unwrap_or(f64::MIN)),
                }
            }
            if let Some(i) = src {
                self.egress[i].reserve(start, start + duration);
            }
            if let Some(i) = dst {
                self.ingress[i].reserve(start, start + duration);
            }
        }
        let wait = start - ready;
        if let Some(i) = src {
            let s = &mut self.stats[i];
            s.egress_busy_seconds += duration;
            s.queue_seconds += wait;
            s.transfers += 1;
        }
        if let Some(i) = dst {
            let s = &mut self.stats[i];
            s.ingress_busy_seconds += duration;
            if src.is_none() {
                s.queue_seconds += wait;
            }
            s.transfers += 1;
        }
        if slow == 1.0 {
            start + tm.time(bytes)
        } else {
            start + tm.latency + duration
        }
    }

    pub fn stats(&self) -> &[LinkStats] {
        &self.stats
    }

    pub fn into_stats(self) -> Vec<LinkStats> {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tm() -> TransferModel {
        TransferModel { bandwidth: 100.0, latency: 0.5 }
    }

    #[test]
    fn free_overlap_matches_migration_time_arithmetic() {
        let t = tm();
        let mut l = LinkScheduler::new(2, false);
        // Two transfers ready at the same instant on the same link must
        // both be delivered at ready + time(bytes) — no serialization.
        let a = l.schedule(&t, 0.0, 1.0, Some(0), Some(1), 200);
        let b = l.schedule(&t, 0.0, 1.0, Some(0), Some(1), 200);
        assert_eq!(a.to_bits(), (1.0 + t.time(200)).to_bits());
        assert_eq!(a.to_bits(), b.to_bits());
        // Busy time still accounted; queueing stays zero.
        assert!((l.stats()[0].egress_busy_seconds - 4.0).abs() < 1e-12);
        assert_eq!(l.stats()[0].queue_seconds, 0.0);
        assert_eq!(l.stats()[0].transfers, 2);
        assert_eq!(l.stats()[1].transfers, 2);
    }

    #[test]
    fn contended_serializes_shared_egress() {
        let t = tm();
        let mut l = LinkScheduler::new(3, true);
        // 200 B at 100 B/s = 2 s on the wire, +0.5 s latency.
        let a = l.schedule(&t, 0.0, 0.0, Some(0), Some(1), 200);
        assert!((a - 2.5).abs() < 1e-12);
        // Same egress, different ingress: waits for the wire, not the peer.
        let b = l.schedule(&t, 0.0, 0.0, Some(0), Some(2), 200);
        assert!((b - 4.5).abs() < 1e-12, "b = {b}");
        assert!((l.stats()[0].queue_seconds - 2.0).abs() < 1e-12);
        // Disjoint channels never serialize (1's egress and 0's ingress
        // are both untouched above).
        let c = l.schedule(&t, 0.0, 0.0, Some(1), Some(0), 100);
        assert!((c - t.time(100)).abs() < 1e-12, "disjoint link starts immediately: {c}");
    }

    #[test]
    fn contended_serializes_shared_ingress() {
        let t = tm();
        let mut l = LinkScheduler::new(3, true);
        let a = l.schedule(&t, 0.0, 0.0, Some(0), Some(2), 200);
        let b = l.schedule(&t, 0.0, 0.0, Some(1), Some(2), 200);
        assert!((b - a - 2.0).abs() < 1e-12, "ingress serializes: {a} {b}");
        // The wait is attributed to the source endpoint.
        assert!((l.stats()[1].queue_seconds - 2.0).abs() < 1e-12);
        assert_eq!(l.stats()[2].queue_seconds, 0.0);
    }

    #[test]
    fn future_reservations_do_not_block_earlier_ready_transfers() {
        // Layer-wise PD streaming reserves windows across a whole prefill
        // pass up front; a transfer ready before those windows must fill
        // the gap, not queue behind the future reservation.
        let t = tm();
        let mut l = LinkScheduler::new(2, true);
        let far = l.schedule(&t, 0.0, 10.0, Some(0), Some(1), 200); // [10, 12)
        assert!((far - 12.5).abs() < 1e-12);
        let early = l.schedule(&t, 0.0, 0.0, Some(0), Some(1), 200); // fits [0, 2)
        assert!((early - 2.5).abs() < 1e-12, "gap before the reservation is usable: {early}");
        assert_eq!(l.stats()[0].queue_seconds, 0.0);
        // A transfer overlapping the future window bumps past it.
        // [9, 11) hits [10, 12) and bumps to [12, 14).
        let bumped = l.schedule(&t, 0.0, 9.0, Some(0), Some(1), 200);
        assert!((bumped - 14.5).abs() < 1e-12, "bumped = {bumped}");
        assert!((l.stats()[0].queue_seconds - 3.0).abs() < 1e-12);
    }

    #[test]
    fn unmodelled_endpoints_skip_accounting() {
        let t = tm();
        let mut l = LinkScheduler::new(1, true);
        let a = l.schedule(&t, 0.0, 0.0, None, None, 1000);
        assert_eq!(a.to_bits(), t.time(1000).to_bits());
        assert_eq!(l.stats()[0].transfers, 0);
        // Destination-only transfer attributes its wait to the ingress.
        l.schedule(&t, 0.0, 0.0, None, Some(0), 100);
        let b = l.schedule(&t, 0.0, 0.0, None, Some(0), 100);
        assert!((b - (1.0 + t.time(100))).abs() < 1e-12);
        assert!((l.stats()[0].queue_seconds - 1.0).abs() < 1e-12);
    }

    #[test]
    fn calendar_prunes_expired_reservations() {
        // A long run must not accumulate every reservation ever made:
        // intervals ending at or before the caller's `now` are dropped.
        let t = tm();
        let mut l = LinkScheduler::new(1, true);
        for k in 0..100u32 {
            let r = k as f64 * 10.0;
            l.schedule(&t, r, r, Some(0), None, 100); // 1 s on the wire each
        }
        assert!(
            l.egress[0].busy.len() <= 2,
            "expired intervals pruned: {}",
            l.egress[0].busy.len()
        );
        assert_eq!(l.stats()[0].transfers, 100);
        assert_eq!(l.stats()[0].queue_seconds, 0.0);
    }

    #[test]
    fn degradation_slows_and_restores_exactly() {
        let t = tm();
        let mut l = LinkScheduler::new(2, false);
        let healthy = l.schedule(&t, 0.0, 0.0, Some(0), Some(1), 200);
        assert_eq!(healthy.to_bits(), t.time(200).to_bits());
        // 3x degradation on either endpoint stretches the wire time only.
        l.set_degradation(1, 3.0);
        assert_eq!(l.degradation(1), 3.0);
        let slow = l.schedule(&t, 0.0, 0.0, Some(0), Some(1), 200);
        assert!((slow - (t.latency + 3.0 * 200.0 / t.bandwidth)).abs() < 1e-12, "slow {slow}");
        // Restoring is bit-exact with the healthy path.
        l.set_degradation(1, 1.0);
        let again = l.schedule(&t, 0.0, 0.0, Some(0), Some(1), 200);
        assert_eq!(again.to_bits(), healthy.to_bits());
        // Out-of-range instance is ignored, not a panic.
        l.set_degradation(99, 2.0);
        assert_eq!(l.degradation(99), 1.0);
    }

    #[test]
    fn degraded_transfers_occupy_the_contended_wire_longer() {
        let t = tm();
        let mut l = LinkScheduler::new(2, true);
        l.set_degradation(0, 2.0);
        // 200 B at 100 B/s x2 = 4 s on the wire.
        let a = l.schedule(&t, 0.0, 0.0, Some(0), Some(1), 200);
        assert!((a - 4.5).abs() < 1e-12, "a {a}");
        let b = l.schedule(&t, 0.0, 0.0, Some(0), Some(1), 200);
        assert!((b - 8.5).abs() < 1e-12, "serialized behind the slow transfer: {b}");
        assert!((l.stats()[0].egress_busy_seconds - 8.0).abs() < 1e-12);
    }

    #[test]
    fn zero_byte_transfers_never_occupy_the_wire() {
        let t = tm();
        let mut l = LinkScheduler::new(1, true);
        l.schedule(&t, 0.0, 0.0, Some(0), None, 200); // [0, 2)
        let z = l.schedule(&t, 0.0, 1.0, Some(0), None, 0);
        assert_eq!(z.to_bits(), (1.0 + t.latency).to_bits(), "latency only, no queueing");
        assert_eq!(l.stats()[0].transfers, 2);
    }
}
