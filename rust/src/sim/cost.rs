//! Analytic stage-latency model, calibrated to A100-class fp16 serving.
//!
//! Encode and prefill are compute-bound (FLOPs / (peak × MFU) plus fixed
//! per-invocation overhead); decode is bandwidth-bound (weights + KV reads
//! per step). Image preprocessing (resize / slice / normalize) runs on host
//! CPU and is significant for 4K images — it shards with IRP because each
//! encode worker preprocesses only its own tiles.
//!
//! Absolute numbers are not expected to match the authors' testbed; the
//! model is calibrated so the *relationships* the paper reports hold:
//! encode-vs-prefill balance per model (InternVL prefill-heavy, MiniCPM
//! encode-light), decode ≈ bandwidth roofline, NPU encode:prefill ratio
//! 10–20% above GPU (App. F.1).

use crate::model::spec::{DeviceSpec, LmmSpec};
use crate::model::vision::Resolution;

/// Fixed software overheads, seconds.
#[derive(Debug, Clone, Copy)]
pub struct Overheads {
    /// Per encode invocation (kernel launches, host sync).
    pub encode_step: f64,
    /// Per prefill invocation.
    pub prefill_step: f64,
    /// Per request within a prefill batch (sampler, detokenizer, python
    /// object churn) — the reason batched prefill beats batch-1 DistServe
    /// in the Fig 10 offline setting.
    pub prefill_per_request: f64,
    /// Per decode step (scheduler + sampler + launch).
    pub decode_step: f64,
    /// Host-side image preprocessing per raw pixel (resize/slice/normalize).
    pub preprocess_per_pixel: f64,
    /// Host-side fixed preprocessing cost per image.
    pub preprocess_per_image: f64,
    /// Fraction of preprocessing that is *image-granular* (resize of the
    /// whole image) and therefore shards across IRP workers only at image
    /// granularity; the rest is slice-granular. Calibrated so Table 4's
    /// IRP speedups come out 1.6–2.9× rather than the naive tile-count
    /// fan-out.
    pub preproc_image_frac: f64,
    /// Cross-request encoder-cache hit path: content-hash lookup plus
    /// pinning the cached blocks (host-side hash of the media bytes is
    /// already paid at admission). Replaces preprocess + encode entirely
    /// on a hit — the whole point of the cache.
    pub cache_lookup: f64,
}

impl Default for Overheads {
    fn default() -> Self {
        Overheads {
            encode_step: 8e-3,
            prefill_step: 10e-3,
            prefill_per_request: 6e-3,
            decode_step: 4e-3,
            preprocess_per_pixel: 4.6e-8,
            preprocess_per_image: 30e-3, // incl. frame extraction for video workloads (Table 1: ~48 ms/frame end-to-end)
            preproc_image_frac: 0.7,
            cache_lookup: 0.5e-3,
        }
    }
}

/// The latency model for one (model, device) pair.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub spec: LmmSpec,
    pub device: DeviceSpec,
    pub overheads: Overheads,
}

impl CostModel {
    pub fn new(spec: LmmSpec, device: DeviceSpec) -> CostModel {
        CostModel { spec, device, overheads: Overheads::default() }
    }

    /// Host preprocessing time for `images` images at `res` (CPU-bound,
    /// before the encoder sees pixels). Under IRP the image-granular part
    /// parallelizes only across images; see [`Self::shard_preprocess_time`].
    pub fn preprocess_time(&self, images: u32, res: Resolution) -> f64 {
        // Audio clips skip frame extraction; their host-side cost is a
        // small resample/feature step.
        let per_item = if matches!(self.spec.vision.tiling, crate::model::spec::TilingPolicy::AudioClip) {
            12e-3
        } else {
            self.overheads.preprocess_per_image
                + res.pixels() as f64 * self.overheads.preprocess_per_pixel
        };
        images as f64 * per_item
    }

    /// Preprocessing attributed to IRP shard `shard_idx`: each image's
    /// resize (the image-granular part) runs once, on the worker holding
    /// that image's first tiles — so only the first `min(fanout, images)`
    /// shards carry it, split evenly. The slice-granular remainder splits
    /// by tile share. Total across shards equals the serial cost.
    pub fn shard_preprocess_time(
        &self,
        images: u32,
        res: Resolution,
        shard_tiles: u32,
        total_tiles: u32,
        fanout: u32,
        shard_idx: u32,
    ) -> f64 {
        if images == 0 || total_tiles == 0 {
            return 0.0;
        }
        let total = self.preprocess_time(images, res);
        let alpha = self.overheads.preproc_image_frac;
        let carriers = fanout.max(1).min(images);
        let image_part = if shard_idx < carriers {
            alpha * total / carriers as f64
        } else {
            0.0
        };
        image_part + (1.0 - alpha) * total * shard_tiles as f64 / total_tiles as f64
    }

    /// Encoder forward time for a batch of `tiles` tiles on one instance.
    /// FLOPs ≈ 2 · params · raw_tokens per tile (dense transformer fwd).
    pub fn encode_time(&self, tiles: u32) -> f64 {
        if tiles == 0 {
            return 0.0;
        }
        let flops_per_tile =
            2.0 * self.spec.vision.params as f64 * self.spec.vision.raw_tokens_per_tile as f64;
        let t = tiles as f64 * flops_per_tile / (self.device.peak_flops * self.device.mfu_encode);
        self.overheads.encode_step + t
    }

    /// Prefill time for a batch totalling `tokens` context tokens.
    /// Linear term: 2 · params · tokens; quadratic attention term:
    /// 2 · layers · hidden · tokens² (flash-attention FLOPs, which at the
    /// paper's multi-image context lengths are no longer negligible).
    pub fn prefill_time(&self, tokens: u64) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        let t = tokens as f64;
        let llm = &self.spec.llm;
        let linear = 2.0 * llm.params as f64 * t;
        let quad = 2.0 * llm.layers as f64 * llm.hidden as f64 * t * t;
        self.overheads.prefill_step
            + (linear + quad) / (self.device.peak_flops * self.device.mfu_prefill)
    }

    /// Incremental prefill cost of extending an already-computed prefix of
    /// `prev` context tokens by `new` tokens — one pass of the chunked EP
    /// streaming pipeline. Summed over a request's passes this equals the
    /// full-context compute plus one per-invocation overhead per pass:
    /// chunking never gets FLOPs for free, it only overlaps them with
    /// encoding and transfer.
    pub fn prefill_extend_time(&self, prev: u64, new: u64) -> f64 {
        if new == 0 {
            return 0.0;
        }
        if prev == 0 {
            return self.prefill_time(new);
        }
        self.overheads.prefill_step + self.prefill_time(prev + new) - self.prefill_time(prev)
    }

    /// One decode step for a batch of `batch` sequences with mean context
    /// `avg_ctx`. Bandwidth-bound: every step reads the weights once and
    /// each sequence's KV cache.
    pub fn decode_step_time(&self, batch: u32, avg_ctx: u64) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let weight_read = self.spec.llm_weight_bytes() as f64 / self.device.hbm_bw;
        let kv_read = batch as f64 * avg_ctx as f64 * self.spec.llm.kv_bytes_per_token() as f64
            / self.device.hbm_bw;
        self.overheads.decode_step + weight_read + kv_read
    }

    /// Encode-stage service time on an encoder-cache *hit*: the lookup
    /// overhead alone — preprocessing and the encoder forward are skipped
    /// because the MM tokens already sit in cache blocks.
    pub fn cache_hit_time(&self) -> f64 {
        self.overheads.cache_lookup
    }

    /// Encode-stage service time on an encoder-cache *miss* (the cost a
    /// hit avoids): host preprocessing plus the encoder forward for all of
    /// the request's tiles. Queueing and EP transfer are extra.
    pub fn cache_miss_time(&self, images: u32, res: Resolution, tiles: u32) -> f64 {
        self.preprocess_time(images, res) + self.encode_time(tiles)
    }

    /// End-to-end single-request service time (no queueing): preprocessing
    /// + encode + prefill + decode of `out` tokens. Used by SJF cost
    /// estimation and sanity tests.
    pub fn unloaded_request_time(
        &self,
        images: u32,
        res: Resolution,
        prompt_tokens: u32,
        out: u32,
    ) -> f64 {
        let tiles = crate::model::vision::tiles_for_image(&self.spec, res) * images;
        let mm = crate::model::vision::mm_tokens_for_image(&self.spec, res) * images as u64;
        let ctx = mm + prompt_tokens as u64;
        let mut t = self.preprocess_time(images, res) + self.encode_time(tiles) + self.prefill_time(ctx);
        for i in 0..out.saturating_sub(1) {
            t += self.decode_step_time(1, ctx + i as u64);
        }
        t
    }

    /// Encode:prefill latency ratio for a workload unit (App. F.1's
    /// diagnostic; the NPU profile must come out 10–20% above the GPU's).
    pub fn encode_prefill_ratio(&self, images: u32, res: Resolution, prompt_tokens: u32) -> f64 {
        let tiles = crate::model::vision::tiles_for_image(&self.spec, res) * images;
        let mm = crate::model::vision::mm_tokens_for_image(&self.spec, res) * images as u64;
        let enc = self.preprocess_time(images, res) + self.encode_time(tiles);
        let pf = self.prefill_time(mm + prompt_tokens as u64);
        enc / pf
    }
}

/// Fault-injection seam: per-instance service-time multipliers layered on
/// top of the cost model. A straggling instance takes `factor`× as long
/// for every stage step it runs; the all-ones map is the exact identity
/// (`stretch` returns its input untouched, bit for bit), so a run with no
/// stragglers is indistinguishable from one without the seam.
#[derive(Debug, Clone, PartialEq)]
pub struct StragglerMap {
    factors: Vec<f64>,
}

impl StragglerMap {
    /// All instances healthy (factor 1.0).
    pub fn uniform(n: usize) -> StragglerMap {
        StragglerMap { factors: vec![1.0; n] }
    }

    /// Set `instance`'s multiplier; out-of-range indices are ignored.
    pub fn set(&mut self, instance: usize, factor: f64) {
        if instance < self.factors.len() {
            self.factors[instance] = factor.max(1e-9);
        }
    }

    /// Current multiplier for `instance` (1.0 when unknown).
    pub fn factor(&self, instance: usize) -> f64 {
        self.factors.get(instance).copied().unwrap_or(1.0)
    }

    /// Stretch a stage duration by `instance`'s multiplier. Healthy
    /// instances return `duration` unchanged (no arithmetic applied).
    pub fn stretch(&self, instance: usize, duration: f64) -> f64 {
        let f = self.factor(instance);
        if f == 1.0 {
            duration
        } else {
            duration * f
        }
    }

    /// Number of instances with a non-unit multiplier.
    pub fn slowed(&self) -> u64 {
        self.factors.iter().filter(|&&f| f != 1.0).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::spec::ModelId;

    fn cm(id: ModelId) -> CostModel {
        CostModel::new(LmmSpec::get(id), DeviceSpec::a100())
    }

    #[test]
    fn decode_step_near_bandwidth_roofline() {
        // MiniCPM 7.6B fp16 on A100: weight read alone = 15.2e9/2e12 = 7.6ms.
        let c = cm(ModelId::MiniCpmV26);
        let t = c.decode_step_time(1, 1000);
        assert!(t > 0.0076 && t < 0.02, "t = {t}");
        // Batch grows cost only via KV reads, far less than linearly.
        let t8 = c.decode_step_time(8, 1000);
        assert!(t8 < 8.0 * t * 0.25, "batched decode amortizes weights: {t8} vs {t}");
    }

    #[test]
    fn internvl_is_prefill_heavy_minicpm_is_not() {
        // §4.1: "InternVL, which is prefill-heavy ... MiniCPM-V, optimized
        // to generate fewer image tokens".
        let res = Resolution::four_k();
        let ratio_ivl = cm(ModelId::InternVl2_8b).encode_prefill_ratio(4, res, 22);
        let ratio_mini = cm(ModelId::MiniCpmV26).encode_prefill_ratio(4, res, 22);
        assert!(
            ratio_mini > 2.0 * ratio_ivl,
            "minicpm {ratio_mini} vs internvl {ratio_ivl}"
        );
    }

    #[test]
    fn npu_ratio_10_to_20_pct_above_gpu() {
        // App. F.1: encode:prefill latency ratio is ~10–20% larger on NPU.
        let spec = LmmSpec::get(ModelId::InternVl2_8b);
        let res = Resolution::four_k();
        let gpu = CostModel::new(spec.clone(), DeviceSpec::a100());
        let npu = CostModel::new(spec, DeviceSpec::npu_910b3());
        // Compare pure device-side ratios (exclude host preprocessing,
        // which is testbed CPU, not accelerator).
        let g = gpu.encode_time(52) / gpu.prefill_time(13_334);
        let n = npu.encode_time(52) / npu.prefill_time(13_334);
        let rel = n / g;
        assert!(rel > 1.08 && rel < 1.30, "rel = {rel}");
    }

    #[test]
    fn prefill_grows_superlinearly() {
        let c = cm(ModelId::InternVl2_8b);
        let t1 = c.prefill_time(3328);
        let t4 = c.prefill_time(4 * 3328);
        assert!(t4 > 3.9 * t1, "quadratic term visible: {t4} vs {t1}");
    }

    #[test]
    fn preprocess_scales_with_pixels() {
        let c = cm(ModelId::MiniCpmV26);
        let small = c.preprocess_time(1, Resolution::new(313, 234));
        let large = c.preprocess_time(1, Resolution::four_k());
        assert!(large > 10.0 * small);
        assert!(large > 0.4 && large < 0.9, "4K preprocess ≈ 0.62s: {large}");
    }

    #[test]
    fn unloaded_ttft_magnitudes_plausible() {
        // Sanity: TTFT-scale service times in the right ballpark of the
        // paper's SLOs (Table 9: MiniCPM 2-image TTFT SLO = 1.40 s).
        // DistServe-style serial service for 2 images must MISS the 1.40 s
        // TTFT SLO (the paper's baselines sit just above it, Fig 6a), while
        // EPD with IRP lands under it.
        let c = cm(ModelId::MiniCpmV26);
        let res = Resolution::four_k();
        let serial = c.preprocess_time(2, res) + c.encode_time(20) + c.prefill_time(1302);
        assert!(serial > 1.40 && serial < 2.2, "serial 2-image MiniCPM ≈ {serial}");
        let shard = c.shard_preprocess_time(2, res, 4, 20, 5, 0) + c.encode_time(4);
        let epd = shard + c.prefill_time(1302);
        assert!(epd < 1.40, "EPD 2-image MiniCPM ≈ {epd}");

        let c26 = cm(ModelId::InternVl2_26b);
        let res26 = Resolution::four_k();
        let serial26 = c26.preprocess_time(4, res26) + c26.encode_time(52)
            + c26.prefill_time(13_334);
        // Serial (DistServe-style) service exceeds the 7.05 s SLO; EPD's
        // IRP sharding lands under it.
        assert!(serial26 > 7.05 && serial26 < 14.0, "serial 4-img InternVL-26B ≈ {serial26}");
        let epd26 = c26.shard_preprocess_time(4, res26, 11, 52, 5, 0)
            + c26.encode_time(11)
            + c26.prefill_time(13_334);
        assert!(epd26 < 7.05, "EPD with IRP under SLO: {epd26}");
    }

    #[test]
    fn extend_passes_sum_to_full_prefill_plus_overheads() {
        let c = cm(ModelId::InternVl2_8b);
        let total = 13_334u64;
        let chunk = 1024u64;
        let mut done = 0u64;
        let mut passes = 0u32;
        let mut sum = 0.0;
        while done < total {
            let new = chunk.min(total - done);
            sum += c.prefill_extend_time(done, new);
            done += new;
            passes += 1;
        }
        let full = c.prefill_time(total);
        let expected = full + (passes as f64 - 1.0) * c.overheads.prefill_step;
        assert!(
            (sum - expected).abs() < 1e-9,
            "sum {sum} vs full-plus-overheads {expected}"
        );
        assert!(sum > full, "chunking pays extra invocation overhead");
    }

    #[test]
    fn extend_degenerate_cases() {
        let c = cm(ModelId::MiniCpmV26);
        assert_eq!(c.prefill_extend_time(1000, 0), 0.0);
        assert_eq!(c.prefill_extend_time(0, 512), c.prefill_time(512));
        // Later passes cost more per token (quadratic attention tail).
        let early = c.prefill_extend_time(0, 1024);
        let late = c.prefill_extend_time(12_000, 1024);
        assert!(late > early, "late {late} vs early {early}");
    }

    #[test]
    fn cache_hit_orders_of_magnitude_under_miss() {
        // The tentpole claim: a hit pays a lookup, a miss pays host
        // preprocessing + the encoder forward. At the paper's default
        // workload unit (2 × 4K images) that gap is >1000×; the bench
        // gate (`benches/perf_encoder_cache.rs`) enforces ≥10×.
        let c = cm(ModelId::MiniCpmV26);
        let res = Resolution::four_k();
        let miss = c.cache_miss_time(2, res, 20);
        let hit = c.cache_hit_time();
        assert!(hit > 0.0);
        assert!(miss / hit >= 10.0, "miss {miss} vs hit {hit}");
    }

    #[test]
    fn zero_work_is_zero_or_overhead_free() {
        let c = cm(ModelId::MiniCpmV26);
        assert_eq!(c.encode_time(0), 0.0);
        assert_eq!(c.prefill_time(0), 0.0);
        assert_eq!(c.decode_step_time(0, 100), 0.0);
    }

    #[test]
    fn straggler_map_identity_and_stretch() {
        let mut m = StragglerMap::uniform(3);
        assert_eq!(m.slowed(), 0);
        // Healthy path is the exact identity, bit for bit.
        let d = 0.123_456_789_f64;
        assert_eq!(m.stretch(0, d).to_bits(), d.to_bits());
        assert_eq!(m.stretch(99, d).to_bits(), d.to_bits(), "unknown instance is healthy");
        m.set(1, 1.5);
        assert_eq!(m.slowed(), 1);
        assert!((m.stretch(1, 2.0) - 3.0).abs() < 1e-12);
        assert_eq!(m.stretch(0, d).to_bits(), d.to_bits(), "others untouched");
        m.set(99, 2.0); // ignored, no panic
        assert_eq!(m.factor(99), 1.0);
        m.set(1, 1.0);
        assert_eq!(m.slowed(), 0);
    }
}
