//! Dense slab arena for per-request simulator state.
//!
//! The engine's hot path touches request state on every event; a
//! `HashMap<RequestId, ReqState>` pays a hash + probe per touch and keeps
//! every request ever admitted resident until the run ends. The slab
//! replaces both costs: requests live in a dense `Vec` indexed by a
//! sequentially assigned `u32` slot (one bounds-checked load per touch),
//! and a slot is recycled through a free list the moment its request
//! finishes — so live memory is bounded by *in-flight* requests, not by
//! workload size. [`Slab::peak_live`] is the peak-RSS proxy the
//! `perf_sim_throughput` bench gates.
//!
//! Slot numbering is deterministic (LIFO free-list reuse), and nothing in
//! the engine orders decisions by slot value, so replacing the map is
//! outcome-preserving.

/// A dense slab with `u32` keys and free-slot reuse.
#[derive(Debug, Clone)]
pub struct Slab<T> {
    slots: Vec<Option<T>>,
    free: Vec<u32>,
    live: usize,
    peak_live: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Slab::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Slab<T> {
        Slab { slots: Vec::new(), free: Vec::new(), live: 0, peak_live: 0 }
    }

    /// Insert a value, returning its slot.
    pub fn insert(&mut self, value: T) -> u32 {
        self.live += 1;
        if self.live > self.peak_live {
            self.peak_live = self.live;
        }
        match self.free.pop() {
            Some(idx) => {
                debug_assert!(self.slots[idx as usize].is_none());
                self.slots[idx as usize] = Some(value);
                idx
            }
            None => {
                let idx = u32::try_from(self.slots.len()).expect("slab overflow");
                self.slots.push(Some(value));
                idx
            }
        }
    }

    /// Remove and return a slot's value; the slot is recycled. Panics on
    /// a vacant slot — a stale handle is a bug, never silent.
    pub fn remove(&mut self, idx: u64) -> T {
        let v = self.slots[idx as usize].take().expect("slab remove of vacant slot");
        self.live -= 1;
        self.free.push(idx as u32);
        v
    }

    pub fn get(&self, idx: u64) -> Option<&T> {
        self.slots.get(idx as usize).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, idx: u64) -> Option<&mut T> {
        self.slots.get_mut(idx as usize).and_then(|s| s.as_mut())
    }

    pub fn contains(&self, idx: u64) -> bool {
        self.get(idx).is_some()
    }

    /// Occupied slots right now.
    pub fn live(&self) -> usize {
        self.live
    }

    /// High-water mark of simultaneously occupied slots — the live
    /// request-state bound the throughput bench gates.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Drop every value and reset the watermark, keeping the slot and
    /// free-list allocations — the recycling hook for pooled simulator
    /// runs ([`crate::sim::engine::SimPool`]). A cleared slab assigns
    /// slots exactly like a fresh one.
    pub fn clear(&mut self) {
        self.slots.clear();
        self.free.clear();
        self.live = 0;
        self.peak_live = 0;
    }

    /// Iterate occupied slots in slot order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, &T)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|v| (i as u32, v)))
    }

    /// Consume the slab, yielding remaining values in slot order.
    pub fn into_values(self) -> impl Iterator<Item = T> {
        self.slots.into_iter().flatten()
    }
}

impl<T> std::ops::Index<u64> for Slab<T> {
    type Output = T;
    #[inline]
    fn index(&self, idx: u64) -> &T {
        self.slots[idx as usize].as_ref().expect("slab index of vacant slot")
    }
}

impl<T> std::ops::IndexMut<u64> for Slab<T> {
    #[inline]
    fn index_mut(&mut self, idx: u64) -> &mut T {
        self.slots[idx as usize].as_mut().expect("slab index of vacant slot")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s: Slab<String> = Slab::new();
        let a = s.insert("a".into());
        let b = s.insert("b".into());
        assert_eq!(a, 0);
        assert_eq!(b, 1);
        assert_eq!(s[a as u64], "a");
        assert_eq!(s.live(), 2);
        assert_eq!(s.remove(a as u64), "a");
        assert_eq!(s.live(), 1);
        assert!(!s.contains(a as u64));
        assert!(s.contains(b as u64));
    }

    #[test]
    fn slots_are_recycled_and_peak_tracks_high_water() {
        let mut s: Slab<u64> = Slab::new();
        for i in 0..4 {
            s.insert(i);
        }
        assert_eq!(s.peak_live(), 4);
        s.remove(3);
        s.remove(1);
        // LIFO reuse: last freed first.
        assert_eq!(s.insert(10), 1);
        assert_eq!(s.insert(11), 3);
        assert_eq!(s.insert(12), 4, "fresh slot only when free list empty");
        assert_eq!(s.peak_live(), 5);
        assert_eq!(s.live(), 5);
    }

    #[test]
    fn live_stays_bounded_under_churn() {
        let mut s: Slab<u64> = Slab::new();
        for i in 0..10_000u64 {
            let idx = s.insert(i);
            assert_eq!(s.remove(idx as u64), i);
        }
        assert_eq!(s.live(), 0);
        assert_eq!(s.peak_live(), 1, "sequential churn never grows the slab");
    }

    #[test]
    #[should_panic(expected = "vacant")]
    fn stale_handle_panics() {
        let mut s: Slab<u64> = Slab::new();
        let idx = s.insert(7);
        s.remove(idx as u64);
        let _ = s[idx as u64];
    }

    #[test]
    fn clear_restores_fresh_slot_numbering() {
        let mut s: Slab<u64> = Slab::new();
        for i in 0..5 {
            s.insert(i);
        }
        s.remove(2);
        s.clear();
        assert!(s.is_empty());
        assert_eq!(s.peak_live(), 0, "watermark resets with the contents");
        assert_eq!(s.insert(40), 0, "slot numbering restarts at zero");
        assert_eq!(s.insert(41), 1);
        assert_eq!(s.peak_live(), 2);
    }

    #[test]
    fn iterates_in_slot_order() {
        let mut s: Slab<u64> = Slab::new();
        let a = s.insert(10);
        let b = s.insert(20);
        let c = s.insert(30);
        s.remove(b as u64);
        let got: Vec<(u32, u64)> = s.iter().map(|(i, &v)| (i, v)).collect();
        assert_eq!(got, vec![(a, 10), (c, 30)]);
        let vals: Vec<u64> = s.into_values().collect();
        assert_eq!(vals, vec![10, 30]);
    }
}
