//! The DistServe-style discrete-event cluster simulator (§3.2.3: "we rely
//! on a simulator — extended from DistServe — to evaluate performance
//! metrics efficiently").
//!
//! The simulator drives the same policy components as the real engine
//! (queues, batchers, block managers, IRP planner, the online
//! reallocation planner and its greedy role-switch fallback) over
//! virtual time, with stage latencies from the analytic [`cost`] model. It simulates all three deployment modes — EPD, PD-disaggregated
//! (DistServe) and aggregated (vLLM) — on A100 or Ascend-910B3 device
//! profiles.
//!
//! [`fault`] layers deterministic chaos injection on top: seeded
//! [`FaultPlan`]s of instance crashes, link degradation, stragglers and
//! encoder OOMs, bit-for-bit dormant when the plan is empty.

pub mod arena;
pub mod cost;
pub mod event;
pub mod engine;
pub mod fault;
pub mod link;
pub mod outcome;

pub use arena::Slab;
pub use cost::{CostModel, StragglerMap};
pub use engine::{SimConfig, SimPool, Simulator};
pub use fault::{FaultPlan, ResilienceStats};
pub use link::{LinkScheduler, LinkStats};
pub use outcome::{AdmissionStats, EpOverlapStats, PdOverlapStats, SimOutcome, StreamedMetrics};
