//! Simulation results: per-request timelines plus system-level counters.

use crate::cache::EncoderCacheStats;
use crate::coordinator::planner::ReallocationStats;
use crate::core::request::RequestTimeline;
use crate::core::slo::Slo;
use crate::sim::link::LinkStats;
use crate::util::stats::{self, Summary};

/// Counters for the chunked encode→prefill streaming pipeline
/// (`EpdConfig::ep_chunk_tokens > 0`). All zero under the monolithic
/// handoff — asserting that is how the regression tests prove the
/// streaming machinery stays fully dormant at chunk size 0.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpOverlapStats {
    /// Streamed EP chunk transfers that landed at the prefill side.
    pub chunks: u64,
    /// Requests that entered the streaming pipeline (media requests in
    /// EPD mode, including encoder-cache hits streaming cached chunks).
    pub streamed_requests: u64,
    /// Partial prefill passes executed over streamed prefixes.
    pub prefill_passes: u64,
    /// Seconds of prefill compute that ran before the owning request's
    /// encode finished (per request: `encode_end - prefill_start` when
    /// positive) — the TTFT the overlap recovered. For fused EP modes this
    /// accumulates the host-preprocess time hidden behind device compute.
    pub overlap_seconds: f64,
}

/// Counters for the prefill→decode handoff. The `handoff_*`,
/// `monolithic_transfers`, `parked` and `kv_bytes` fields accumulate in
/// *every* mode (they are how the streamed-vs-monolithic A/B is
/// measured); the streaming-specific fields (`streamed_requests`,
/// `chunks`, `retargets`, `fallbacks`) stay zero under the monolithic
/// handoff (`pd_layer_groups = 0`) — asserting that is how the
/// regression tests prove the machinery stays dormant.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PdOverlapStats {
    /// Requests that entered the layer-wise streaming pipeline (decode
    /// target selected and KV blocks reserved at prefill start).
    pub streamed_requests: u64,
    /// Streamed layer-group transfers that landed at a decode target.
    pub chunks: u64,
    /// Mid-stream re-targets: the chosen decoder stopped serving decode
    /// (role switch) before the tail group landed, so already-landed KV
    /// was re-sent to a fresh target.
    pub retargets: u64,
    /// Requests whose early decode selection found no decoder able to
    /// host their context — they fell back to the monolithic handoff.
    pub fallbacks: u64,
    /// Requests parked at the PD edge because *no* instance served
    /// decode (all mid-switch); woken event-driven by the next
    /// `SwitchDone` that restores the role — never polled.
    pub parked: u64,
    /// Monolithic full-KV transfers completed (exactly one per
    /// non-streamed multi-token request; a polling retry loop would
    /// inflate this, which is what the regression test pins).
    pub monolithic_transfers: u64,
    /// Bytes moved over the PD edge (monolithic + streamed + re-sent).
    /// Invariant between `pd_layer_groups = 0` and `> 0` when no
    /// re-targets occur — streaming never moves KV it didn't have to.
    pub kv_bytes: u64,
    /// Σ over decode admissions of `join_time − prefill_end`: the
    /// prefill-end→decode-start latency the streamed handoff collapses.
    pub handoff_seconds: f64,
    /// Decode admissions measured into `handoff_seconds`.
    pub handoff_count: u64,
}

impl PdOverlapStats {
    /// Mean prefill-end→decode-start latency, seconds.
    pub fn mean_handoff(&self) -> f64 {
        if self.handoff_count == 0 {
            return 0.0;
        }
        self.handoff_seconds / self.handoff_count as f64
    }
}

/// The result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    pub timelines: Vec<RequestTimeline>,
    /// Virtual time at which the last request finished.
    pub makespan: f64,
    /// Role switches performed (§3.2.4).
    pub role_switches: u32,
    /// Reallocation-planner counters: plans adopted, steps planned /
    /// released / gate-blocked, stale plans dropped. All zero when
    /// `role_switching` is off; under the default `planner = "greedy"`
    /// every executed switch is a one-step plan.
    pub reallocation: ReallocationStats,
    /// Per-stage busy time across instances (E, P, D), seconds.
    pub busy: [f64; 3],
    /// Requests rejected at admission (cache exhaustion with no recovery).
    pub rejected: u32,
    /// Cross-request encoder-cache counters. All zero when the workload
    /// carries no `media_hash`; with the cache disabled (capacity 0),
    /// `hits`/`insertions` stay zero but lookups still count as `misses`
    /// and population attempts as `rejected`.
    pub encoder_cache: EncoderCacheStats,
    /// Chunked EP streaming counters (`ep_chunk_tokens > 0` only).
    pub ep_overlap: EpOverlapStats,
    /// Prefill→decode handoff counters (layer-wise KV streaming when
    /// `pd_layer_groups > 0`; handoff-latency accounting always).
    pub pd_overlap: PdOverlapStats,
    /// Per-instance link counters (egress/ingress busy time, queueing
    /// delay). Queueing is non-zero only with `link_contention` enabled.
    pub links: Vec<LinkStats>,
}

impl SimOutcome {
    pub fn finished(&self) -> impl Iterator<Item = &RequestTimeline> {
        self.timelines.iter().filter(|t| t.is_finished())
    }

    pub fn ttfts(&self) -> Vec<f64> {
        self.finished().map(|t| t.ttft()).collect()
    }

    pub fn tpots(&self) -> Vec<f64> {
        self.finished().map(|t| t.tpot()).collect()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.finished().map(|t| t.latency()).collect()
    }

    pub fn mean_ttft(&self) -> f64 {
        stats::mean(&self.ttfts())
    }

    pub fn mean_tpot(&self) -> f64 {
        stats::mean(&self.tpots())
    }

    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies())
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttfts())
    }

    /// Fraction of submitted requests meeting both TTFT and TPOT SLOs
    /// (unfinished/rejected requests count as misses — §4's definition).
    pub fn slo_attainment(&self, slo: Slo) -> f64 {
        let total = self.timelines.len() + self.rejected as usize;
        if total == 0 {
            return 0.0;
        }
        let ok = self
            .finished()
            .filter(|t| slo.attained(t.ttft(), t.tpot()))
            .count();
        ok as f64 / total as f64
    }

    /// Total seconds transfers spent queued behind busy links (zero
    /// unless `link_contention` is enabled).
    pub fn link_queue_seconds(&self) -> f64 {
        self.links.iter().map(|l| l.queue_seconds).sum()
    }

    /// Total link occupancy across instances (egress + ingress), seconds.
    pub fn link_busy_seconds(&self) -> f64 {
        self.links
            .iter()
            .map(|l| l.egress_busy_seconds + l.ingress_busy_seconds)
            .sum()
    }

    /// Completed requests per second of makespan (offline throughput).
    pub fn throughput(&self) -> f64 {
        let n = self.finished().count();
        if self.makespan <= 0.0 {
            return 0.0;
        }
        n as f64 / self.makespan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::RequestTimeline;

    fn tl(id: u64, arrival: f64, first: f64, finish: f64, out: u32) -> RequestTimeline {
        let mut t = RequestTimeline::new(id, arrival);
        t.first_token = first;
        t.finish = finish;
        t.output_tokens = out;
        t
    }

    fn outcome() -> SimOutcome {
        SimOutcome {
            timelines: vec![
                tl(1, 0.0, 1.0, 2.0, 10),  // ttft 1.0, tpot ~0.111
                tl(2, 0.0, 3.0, 4.0, 10),  // ttft 3.0
                RequestTimeline::new(3, 0.0), // never finished
            ],
            makespan: 4.0,
            role_switches: 0,
            reallocation: ReallocationStats::default(),
            busy: [1.0, 1.0, 1.0],
            rejected: 1,
            encoder_cache: EncoderCacheStats::default(),
            ep_overlap: EpOverlapStats::default(),
            pd_overlap: PdOverlapStats::default(),
            links: Vec::new(),
        }
    }

    #[test]
    fn attainment_counts_unfinished_and_rejected_as_misses() {
        let o = outcome();
        // SLO admits only request 1 → 1 of (3 timelines + 1 rejected).
        let att = o.slo_attainment(Slo::new(2.0, 0.2));
        assert!((att - 0.25).abs() < 1e-12);
    }

    #[test]
    fn mean_metrics_ignore_unfinished() {
        let o = outcome();
        assert!((o.mean_ttft() - 2.0).abs() < 1e-12);
        assert_eq!(o.ttfts().len(), 2);
    }

    #[test]
    fn throughput() {
        let o = outcome();
        assert!((o.throughput() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn mean_handoff_guards_empty() {
        let mut s = PdOverlapStats::default();
        assert_eq!(s.mean_handoff(), 0.0);
        s.handoff_seconds = 3.0;
        s.handoff_count = 2;
        assert!((s.mean_handoff() - 1.5).abs() < 1e-12);
    }
}
