//! Simulation results: per-request timelines plus system-level counters.

use crate::cache::EncoderCacheStats;
use crate::coordinator::planner::ReallocationStats;
use crate::core::request::RequestTimeline;
use crate::core::slo::Slo;
use crate::router::RouterStats;
use crate::sim::fault::ResilienceStats;
use crate::sim::link::LinkStats;
use crate::util::json::Json;
use crate::util::stats::{self, QuantileSketch, Summary};

/// Counters for the chunked encode→prefill streaming pipeline
/// (`EpdConfig::ep_chunk_tokens > 0`). All zero under the monolithic
/// handoff — asserting that is how the regression tests prove the
/// streaming machinery stays fully dormant at chunk size 0.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct EpOverlapStats {
    /// Streamed EP chunk transfers that landed at the prefill side.
    pub chunks: u64,
    /// Requests that entered the streaming pipeline (media requests in
    /// EPD mode, including encoder-cache hits streaming cached chunks).
    pub streamed_requests: u64,
    /// Partial prefill passes executed over streamed prefixes.
    pub prefill_passes: u64,
    /// Seconds of prefill compute that ran before the owning request's
    /// encode finished (per request: `encode_end - prefill_start` when
    /// positive) — the TTFT the overlap recovered. For fused EP modes this
    /// accumulates the host-preprocess time hidden behind device compute.
    pub overlap_seconds: f64,
}

/// Counters for the prefill→decode handoff. The `handoff_*`,
/// `monolithic_transfers`, `parked` and `kv_bytes` fields accumulate in
/// *every* mode (they are how the streamed-vs-monolithic A/B is
/// measured); the streaming-specific fields (`streamed_requests`,
/// `chunks`, `retargets`, `fallbacks`) stay zero under the monolithic
/// handoff (`pd_layer_groups = 0`) — asserting that is how the
/// regression tests prove the machinery stays dormant.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PdOverlapStats {
    /// Requests that entered the layer-wise streaming pipeline (decode
    /// target selected and KV blocks reserved at prefill start).
    pub streamed_requests: u64,
    /// Streamed layer-group transfers that landed at a decode target.
    pub chunks: u64,
    /// Mid-stream re-targets: the chosen decoder stopped serving decode
    /// (role switch) before the tail group landed, so already-landed KV
    /// was re-sent to a fresh target.
    pub retargets: u64,
    /// Requests whose early decode selection found no decoder able to
    /// host their context — they fell back to the monolithic handoff.
    pub fallbacks: u64,
    /// Requests parked at the PD edge because *no* instance served
    /// decode (all mid-switch); woken event-driven by the next
    /// `SwitchDone` that restores the role — never polled.
    pub parked: u64,
    /// Monolithic full-KV transfers completed (exactly one per
    /// non-streamed multi-token request; a polling retry loop would
    /// inflate this, which is what the regression test pins).
    pub monolithic_transfers: u64,
    /// Bytes moved over the PD edge (monolithic + streamed + re-sent).
    /// Invariant between `pd_layer_groups = 0` and `> 0` when no
    /// re-targets occur — streaming never moves KV it didn't have to.
    pub kv_bytes: u64,
    /// Σ over decode admissions of `join_time − prefill_end`: the
    /// prefill-end→decode-start latency the streamed handoff collapses.
    pub handoff_seconds: f64,
    /// Decode admissions measured into `handoff_seconds`.
    pub handoff_count: u64,
}

impl PdOverlapStats {
    /// Mean prefill-end→decode-start latency, seconds.
    pub fn mean_handoff(&self) -> f64 {
        if self.handoff_count == 0 {
            return 0.0;
        }
        self.handoff_seconds / self.handoff_count as f64
    }
}

/// Streaming metrics accumulated at request completion, in O(1) memory.
///
/// Always populated (the sketches cost nanoseconds per finish); they are
/// the *only* metric source when `SimConfig::record_timelines = false`,
/// where per-request timelines are dropped the moment a request finishes
/// and live state stays bounded by in-flight requests. Sketch means are
/// exact; percentiles carry the sketch's relative-error bound (default
/// 1%, see [`QuantileSketch`]).
#[derive(Debug, Clone, Default)]
pub struct StreamedMetrics {
    /// TTFT sketch over finished requests.
    pub ttft: QuantileSketch,
    /// TPOT sketch over finished requests.
    pub tpot: QuantileSketch,
    /// End-to-end latency sketch over finished requests.
    pub latency: QuantileSketch,
    /// Requests that finished (excludes rejections).
    pub finished: u64,
    /// Finished requests meeting `slo` — counted online so attainment is
    /// available without timelines. Zero unless `slo` was configured.
    pub slo_attained: u64,
    /// The SLO the online counter was measured against
    /// (`SimConfig::streamed_slo`).
    pub slo: Option<Slo>,
}

/// Admission-parking counters: requests that found every instance of
/// their next stage mid-switch and parked for an event-driven wake at the
/// `SwitchDone` restoring the role. The legacy engine retried these on a
/// 10 ms poll; these counters (and the regression tests pinning small
/// event totals) prove the polling is gone.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct AdmissionStats {
    /// Arrivals parked because no instance accepted entry-stage work.
    pub parked_arrivals: u64,
    /// Requests parked at the EP→prefill edge (every prefill instance
    /// switching).
    pub parked_prefill: u64,
}

/// The result of one simulation run.
#[derive(Debug, Clone)]
pub struct SimOutcome {
    /// Per-request timelines, sorted by request id. Empty when
    /// `timelines_recorded` is false — use [`SimOutcome::streamed`] then.
    pub timelines: Vec<RequestTimeline>,
    /// Whether per-request timelines were recorded
    /// (`SimConfig::record_timelines`).
    pub timelines_recorded: bool,
    /// Requests submitted (finished + unfinished + rejected).
    pub submitted: usize,
    /// O(1)-memory streaming metrics (sketch percentiles, exact means).
    pub streamed: StreamedMetrics,
    /// Events dispatched over the run — the throughput bench's
    /// numerator.
    pub events_processed: u64,
    /// Peak simultaneously live request states (the slab arena's
    /// high-water mark): the peak-RSS proxy, bounded by in-flight — not
    /// total — requests.
    pub peak_live_requests: usize,
    /// Event-driven admission-parking counters (poll-free blocking).
    pub admission: AdmissionStats,
    /// Virtual time at which the last request finished.
    pub makespan: f64,
    /// Role switches performed (§3.2.4).
    pub role_switches: u32,
    /// Reallocation-planner counters: plans adopted, steps planned /
    /// released / gate-blocked, stale plans dropped. All zero when
    /// `role_switching` is off; under the default `planner = "greedy"`
    /// every executed switch is a one-step plan.
    pub reallocation: ReallocationStats,
    /// Per-stage busy time across instances (E, P, D), seconds.
    pub busy: [f64; 3],
    /// Requests rejected at admission (cache exhaustion with no recovery).
    pub rejected: u32,
    /// Cross-request encoder-cache counters. All zero when the workload
    /// carries no `media_hash`; with the cache disabled (capacity 0),
    /// `hits`/`insertions` stay zero but lookups still count as `misses`
    /// and population attempts as `rejected`.
    pub encoder_cache: EncoderCacheStats,
    /// Chunked EP streaming counters (`ep_chunk_tokens > 0` only).
    pub ep_overlap: EpOverlapStats,
    /// Prefill→decode handoff counters (layer-wise KV streaming when
    /// `pd_layer_groups > 0`; handoff-latency accounting always).
    pub pd_overlap: PdOverlapStats,
    /// Per-instance link counters (egress/ingress busy time, queueing
    /// delay). Queueing is non-zero only with `link_contention` enabled.
    pub links: Vec<LinkStats>,
    /// Fault-injection accounting (crashes executed, requests
    /// lost/retried/re-targeted, SLO recovery time and dip). All zeros
    /// when `SimConfig::faults` is the empty plan.
    pub resilience: ResilienceStats,
    /// Front-door counters (text bypass, shed, degraded, held). All
    /// zeros when `router = "off"` — the dormancy property tests pin
    /// exactly that.
    pub router: RouterStats,
}

impl SimOutcome {
    pub fn finished(&self) -> impl Iterator<Item = &RequestTimeline> {
        self.timelines.iter().filter(|t| t.is_finished())
    }

    pub fn ttfts(&self) -> Vec<f64> {
        self.finished().map(|t| t.ttft()).collect()
    }

    pub fn tpots(&self) -> Vec<f64> {
        self.finished().map(|t| t.tpot()).collect()
    }

    pub fn latencies(&self) -> Vec<f64> {
        self.finished().map(|t| t.latency()).collect()
    }

    /// Finished requests, available in both metric modes.
    pub fn finished_requests(&self) -> u64 {
        self.streamed.finished
    }

    /// Mean TTFT: exact from timelines when recorded, exact from the
    /// streaming sum otherwise (sketch means are not approximate).
    pub fn mean_ttft(&self) -> f64 {
        if self.timelines_recorded {
            stats::mean(&self.ttfts())
        } else {
            self.streamed.ttft.mean()
        }
    }

    pub fn mean_tpot(&self) -> f64 {
        if self.timelines_recorded {
            stats::mean(&self.tpots())
        } else {
            self.streamed.tpot.mean()
        }
    }

    pub fn mean_latency(&self) -> f64 {
        if self.timelines_recorded {
            stats::mean(&self.latencies())
        } else {
            self.streamed.latency.mean()
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::of(&self.ttfts())
    }

    /// Fraction of submitted requests meeting both TTFT and TPOT SLOs
    /// (unfinished/rejected requests count as misses — §4's definition).
    /// Without timelines this reads the online counter, which requires
    /// `SimConfig::streamed_slo` to have been set to the same SLO.
    pub fn slo_attainment(&self, slo: Slo) -> f64 {
        if self.timelines_recorded {
            let total = self.timelines.len() + self.rejected as usize;
            if total == 0 {
                return 0.0;
            }
            let ok = self
                .finished()
                .filter(|t| slo.attained(t.ttft(), t.tpot()))
                .count();
            ok as f64 / total as f64
        } else {
            // Loud on misuse: the online counter was measured against
            // `SimConfig::streamed_slo`; answering for any other SLO
            // would return a plausible-looking wrong number.
            assert_eq!(
                self.streamed.slo,
                Some(slo),
                "timeline-free attainment requires SimConfig::streamed_slo == slo"
            );
            if self.submitted == 0 {
                return 0.0;
            }
            self.streamed.slo_attained as f64 / self.submitted as f64
        }
    }

    /// Total seconds transfers spent queued behind busy links (zero
    /// unless `link_contention` is enabled).
    pub fn link_queue_seconds(&self) -> f64 {
        self.links.iter().map(|l| l.queue_seconds).sum()
    }

    /// Total link occupancy across instances (egress + ingress), seconds.
    pub fn link_busy_seconds(&self) -> f64 {
        self.links
            .iter()
            .map(|l| l.egress_busy_seconds + l.ingress_busy_seconds)
            .sum()
    }

    /// Completed requests per second of makespan (offline throughput).
    pub fn throughput(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.streamed.finished as f64 / self.makespan
    }

    /// Full machine-readable dump. Deterministic (BTreeMap-ordered keys,
    /// fixed field set), so byte-identical runs serialize byte-identically
    /// — the golden-determinism tests compare these strings.
    pub fn to_json(&self) -> Json {
        fn sketch(s: &QuantileSketch) -> Json {
            Json::obj(vec![
                ("count", Json::num(s.count() as f64)),
                ("mean", Json::num(s.mean())),
                ("p50", Json::num(s.quantile(0.5))),
                ("p90", Json::num(s.quantile(0.9))),
                ("p99", Json::num(s.quantile(0.99))),
                ("min", Json::num(s.min())),
                ("max", Json::num(s.max())),
            ])
        }
        let mut fields = vec![
            ("makespan", Json::num(self.makespan)),
            ("submitted", Json::num(self.submitted as f64)),
            ("finished", Json::num(self.streamed.finished as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("role_switches", Json::num(self.role_switches as f64)),
            ("events_processed", Json::num(self.events_processed as f64)),
            ("peak_live_requests", Json::num(self.peak_live_requests as f64)),
            ("timelines_recorded", Json::Bool(self.timelines_recorded)),
            ("busy", Json::arr(self.busy.iter().map(|&b| Json::num(b)))),
            (
                "reallocation",
                Json::obj(vec![
                    ("plans", Json::num(self.reallocation.plans as f64)),
                    ("planned_steps", Json::num(self.reallocation.planned_steps as f64)),
                    ("released_steps", Json::num(self.reallocation.released_steps as f64)),
                    ("blocked_steps", Json::num(self.reallocation.blocked_steps as f64)),
                    ("aborted_plans", Json::num(self.reallocation.aborted_plans as f64)),
                    ("surrogate_scored", Json::num(self.reallocation.surrogate_scored as f64)),
                    ("whatif_evals", Json::num(self.reallocation.whatif_evals as f64)),
                    (
                        "forced_explorations",
                        Json::num(self.reallocation.forced_explorations as f64),
                    ),
                ]),
            ),
            (
                "encoder_cache",
                Json::obj(vec![
                    ("hits", Json::num(self.encoder_cache.hits as f64)),
                    ("misses", Json::num(self.encoder_cache.misses as f64)),
                    ("insertions", Json::num(self.encoder_cache.insertions as f64)),
                    ("evictions", Json::num(self.encoder_cache.evictions as f64)),
                    ("rejected", Json::num(self.encoder_cache.rejected as f64)),
                ]),
            ),
            (
                "ep_overlap",
                Json::obj(vec![
                    ("chunks", Json::num(self.ep_overlap.chunks as f64)),
                    ("streamed_requests", Json::num(self.ep_overlap.streamed_requests as f64)),
                    ("prefill_passes", Json::num(self.ep_overlap.prefill_passes as f64)),
                    ("overlap_seconds", Json::num(self.ep_overlap.overlap_seconds)),
                ]),
            ),
            (
                "pd_overlap",
                Json::obj(vec![
                    ("streamed_requests", Json::num(self.pd_overlap.streamed_requests as f64)),
                    ("chunks", Json::num(self.pd_overlap.chunks as f64)),
                    ("retargets", Json::num(self.pd_overlap.retargets as f64)),
                    ("fallbacks", Json::num(self.pd_overlap.fallbacks as f64)),
                    ("parked", Json::num(self.pd_overlap.parked as f64)),
                    (
                        "monolithic_transfers",
                        Json::num(self.pd_overlap.monolithic_transfers as f64),
                    ),
                    ("kv_bytes", Json::num(self.pd_overlap.kv_bytes as f64)),
                    ("handoff_seconds", Json::num(self.pd_overlap.handoff_seconds)),
                    ("handoff_count", Json::num(self.pd_overlap.handoff_count as f64)),
                ]),
            ),
            (
                "admission",
                Json::obj(vec![
                    ("parked_arrivals", Json::num(self.admission.parked_arrivals as f64)),
                    ("parked_prefill", Json::num(self.admission.parked_prefill as f64)),
                ]),
            ),
            (
                "links",
                Json::obj(vec![
                    ("busy_seconds", Json::num(self.link_busy_seconds())),
                    ("queue_seconds", Json::num(self.link_queue_seconds())),
                    (
                        "transfers",
                        Json::num(self.links.iter().map(|l| l.transfers).sum::<u64>() as f64),
                    ),
                ]),
            ),
            ("resilience", self.resilience.to_json()),
            ("router", self.router.to_json()),
            (
                "streamed",
                Json::obj(vec![
                    ("ttft", sketch(&self.streamed.ttft)),
                    ("tpot", sketch(&self.streamed.tpot)),
                    ("latency", sketch(&self.streamed.latency)),
                    ("slo_attained", Json::num(self.streamed.slo_attained as f64)),
                ]),
            ),
        ];
        if self.timelines_recorded {
            fields.push((
                "timelines",
                Json::arr(self.timelines.iter().map(|t| {
                    Json::arr(
                        [
                            t.id as f64,
                            t.arrival,
                            t.encode_start,
                            t.encode_end,
                            t.prefill_start,
                            t.prefill_end,
                            t.first_token,
                            t.finish,
                            t.output_tokens as f64,
                        ]
                        .into_iter()
                        .map(Json::num),
                    )
                })),
            ));
        }
        Json::obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::RequestTimeline;

    fn tl(id: u64, arrival: f64, first: f64, finish: f64, out: u32) -> RequestTimeline {
        let mut t = RequestTimeline::new(id, arrival);
        t.first_token = first;
        t.finish = finish;
        t.output_tokens = out;
        t
    }

    fn outcome() -> SimOutcome {
        let timelines = vec![
            tl(1, 0.0, 1.0, 2.0, 10),  // ttft 1.0, tpot ~0.111
            tl(2, 0.0, 3.0, 4.0, 10),  // ttft 3.0
            RequestTimeline::new(3, 0.0), // never finished
        ];
        let mut streamed = StreamedMetrics::default();
        for t in timelines.iter().filter(|t| t.is_finished()) {
            streamed.ttft.record(t.ttft());
            streamed.tpot.record(t.tpot());
            streamed.latency.record(t.latency());
            streamed.finished += 1;
        }
        SimOutcome {
            timelines,
            timelines_recorded: true,
            submitted: 4,
            streamed,
            events_processed: 0,
            peak_live_requests: 0,
            admission: AdmissionStats::default(),
            makespan: 4.0,
            role_switches: 0,
            reallocation: ReallocationStats::default(),
            busy: [1.0, 1.0, 1.0],
            rejected: 1,
            encoder_cache: EncoderCacheStats::default(),
            ep_overlap: EpOverlapStats::default(),
            pd_overlap: PdOverlapStats::default(),
            links: Vec::new(),
            resilience: ResilienceStats::default(),
            router: RouterStats::default(),
        }
    }

    #[test]
    fn attainment_counts_unfinished_and_rejected_as_misses() {
        let o = outcome();
        // SLO admits only request 1 → 1 of (3 timelines + 1 rejected).
        let att = o.slo_attainment(Slo::new(2.0, 0.2));
        assert!((att - 0.25).abs() < 1e-12);
    }

    #[test]
    fn mean_metrics_ignore_unfinished() {
        let o = outcome();
        assert!((o.mean_ttft() - 2.0).abs() < 1e-12);
        assert_eq!(o.ttfts().len(), 2);
    }

    #[test]
    fn throughput() {
        let o = outcome();
        assert!((o.throughput() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn streamed_fallback_when_timelines_off() {
        let mut o = outcome();
        o.timelines_recorded = false;
        o.timelines.clear();
        let slo = Slo::new(2.0, 0.2);
        o.streamed.slo = Some(slo);
        o.streamed.slo_attained = 1;
        assert!((o.mean_ttft() - 2.0).abs() < 1e-12, "exact mean from the sum");
        assert!((o.slo_attainment(slo) - 0.25).abs() < 1e-12);
        assert!((o.throughput() - 0.5).abs() < 1e-12);
        assert_eq!(o.finished_requests(), 2);
        // p99 carries the sketch bound (1% relative) around the exact 3.0.
        let p99 = o.streamed.ttft.quantile(0.99);
        assert!((p99 - 3.0).abs() <= 0.03 + 1e-12, "p99 {p99}");
    }

    #[test]
    fn json_dump_is_deterministic_and_complete() {
        let o = outcome();
        let a = o.to_json().pretty();
        let b = o.to_json().pretty();
        assert_eq!(a, b);
        let parsed = crate::util::json::Json::parse(&a).unwrap();
        assert_eq!(parsed.get("submitted").and_then(|j| j.as_u64()), Some(4));
        assert_eq!(parsed.get("finished").and_then(|j| j.as_u64()), Some(2));
        assert_eq!(
            parsed.get("timelines").and_then(|j| j.as_arr()).map(|a| a.len()),
            Some(3)
        );
        let res = parsed.get("resilience").expect("resilience block always present");
        parsed.get("router").expect("router block always present");
        assert_eq!(res.get("requests_lost").and_then(|j| j.as_f64()), Some(0.0));
        let mut off = o.clone();
        off.timelines_recorded = false;
        off.timelines.clear();
        let j = off.to_json();
        assert!(j.get("timelines").is_none(), "no per-request payload without timelines");
    }

    #[test]
    fn mean_handoff_guards_empty() {
        let mut s = PdOverlapStats::default();
        assert_eq!(s.mean_handoff(), 0.0);
        s.handoff_seconds = 3.0;
        s.handoff_count = 2;
        assert!((s.mean_handoff() - 1.5).abs() < 1e-12);
    }
}
